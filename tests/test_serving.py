"""Multi-tenant batched serving layer (DESIGN.md §13): batched-vs-
sequential distributional parity (bitwise for keyed draws/walks at bucket
width, TV within precomputed tolerance for stratified and hashed draws),
tenant LRU lifecycle, per-request guard fan-out, the serve CLI's
graph-stream and multi-tenant paths, and an 8-simulated-device subprocess
assertion that batching adds ZERO extra collectives per draw batch.

All distributional assertions derive their keys from ``stats.ROOT_SEED``
and compare against the precomputed critical values of ``tests/stats.py``
(false-positive budget documented there)."""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import stats
from repro.core.kernels_fn import gaussian
from repro.core.serving import (DEFAULT_BUCKETS, KernelGraphServable,
                                shape_bucket)
from repro.kernels.kde_sampler import ops as _ops

N, D = 192, 4


def _data(label, shift=0.0):
    rng = np.random.default_rng(stats.derive_seed("serving", label))
    return (rng.normal(0, 0.6, size=(N, D)) + shift).astype(np.float32)


@pytest.fixture(scope="module")
def srv2():
    """Two flat tenants with IDENTICAL static config (they stack into one
    batch group) over different datasets."""
    s = KernelGraphServable(max_resident=4)
    s.add_tenant("a", _data("a"), gaussian(1.0), block_size=16, seed=3)
    s.add_tenant("b", _data("b", 0.8), gaussian(1.0), block_size=16, seed=4)
    return s


def _cfg(srv, name):
    return srv.tenant(name).admit()._cfg


# ------------------------------------------------------------------- #
# bitwise parity: a served request IS the sequential program
# ------------------------------------------------------------------- #
def test_sample_bitwise_parity_multi_tenant(srv2):
    """Requests at bucket width on two stacked tenants reproduce the
    sequential single-tenant ``fused_sample`` bit-for-bit (same key), and
    ride in ONE batch group."""
    src = np.arange(16)
    ra = srv2.submit("a", "sample", src=src, seed=101)
    rb = srv2.submit("b", "sample", src=src + 32, seed=202)
    st = srv2.tick()
    assert st["groups"] == 1 and ra.error is None and rb.error is None
    for r, name, s in ((ra, "a", src), (rb, "b", src + 32)):
        nbr = srv2.tenant(name).admit()
        nb0, p0, _, _ = _ops.fused_sample(
            nbr.x, nbr.x_sq, jnp.asarray(s, jnp.int32),
            jax.random.PRNGKey(r.seed), **nbr._cfg)
        np.testing.assert_array_equal(r.result[0], np.asarray(nb0))
        np.testing.assert_array_equal(r.result[1], np.asarray(p0))


def test_walk_bitwise_parity(srv2):
    """Keyed walks through the servable equal the sequential walk_scan
    endpoints bitwise (same per-request key stream)."""
    starts, length = np.arange(8), 5
    r = srv2.submit("a", "walk", starts=starts, length=length, seed=77)
    srv2.tick()
    assert r.error is None
    nbr = srv2.tenant("a").admit()
    keys = jax.random.split(jax.random.PRNGKey(77), length)
    e0, _, _, _ = _ops.walk_scan(nbr.x, nbr.x_sq,
                                 jnp.asarray(starts, jnp.int32), keys,
                                 rounds=0, slack=2.0, record_path=False,
                                 **nbr._cfg)
    np.testing.assert_array_equal(r.result[0], np.asarray(e0))


def test_prob_of_bitwise_parity(srv2):
    """Served q(dst | src) equals the sequential masked level-1 read +
    exact level-2 probability with the same key."""
    src, dst = np.arange(16), (np.arange(16) + 5) % N
    r = srv2.submit("b", "prob_of", src=src, dst=dst, seed=55)
    srv2.tick()
    assert r.error is None
    nbr = srv2.tenant("b").admit()
    key = jax.random.PRNGKey(55)
    bs, _ = _ops.masked_block_sums(nbr.x, nbr.x_sq,
                                   jnp.asarray(src, jnp.int32),
                                   key, **nbr._cfg)
    p0, _ = _ops.prob_of_from_block_sums(nbr.x, nbr.x_sq,
                                         jnp.asarray(src, jnp.int32),
                                         jnp.asarray(dst, jnp.int32), bs,
                                         **nbr._l2_cfg)
    np.testing.assert_array_equal(r.result, np.asarray(p0))


def test_query_parity_dense(srv2):
    """Served KDE queries draw the SAME stratified block subsamples as
    the sequential read (same key); the final row-sum is only
    reduction-order-tight (vmap may reassociate the float32 sum), so the
    estimate comparison is allclose at 1e-6, not bitwise."""
    rng = np.random.default_rng(stats.derive_seed("serving", "query"))
    y = rng.normal(0, 0.6, size=(8, D)).astype(np.float32)
    r = srv2.submit("a", "query", y=y, seed=33)
    srv2.tick()
    assert r.error is None
    nbr = srv2.tenant("a").admit()
    c = nbr._cfg
    bs, _ = _ops.stratified_block_sums(
        jnp.asarray(y), nbr.x, nbr.x_sq, jax.random.PRNGKey(33),
        kind=c["kind"], inv_bw=c["inv_bw"], beta=c["beta"],
        pairwise=c["pairwise"], block_size=c["block_size"],
        num_blocks=c["num_blocks"], n=c["n"], s=c["s"])
    np.testing.assert_allclose(r.result, np.asarray(bs.sum(-1)), rtol=1e-6)


def test_hash_tenants_bitwise_sample_and_query():
    """Hashed level-1 tenants: stacked HashState draws and hashed queries
    through the servable are bitwise the sequential per-tenant calls."""
    from repro.kernels.kde_hash import ops as _hops
    srv = KernelGraphServable()
    srv.add_tenant("h1", _data("h1"), gaussian(1.0), level1="hash",
                   block_size=16, seed=5)
    srv.add_tenant("h2", _data("h2", 0.5), gaussian(1.0), level1="hash",
                   block_size=16, seed=6)
    src = np.arange(16)
    rng = np.random.default_rng(stats.derive_seed("serving", "hq"))
    y = rng.normal(0, 0.6, size=(8, D)).astype(np.float32)
    r1 = srv.submit("h1", "sample", src=src, seed=11)
    r2 = srv.submit("h2", "sample", src=src + 8, seed=12)
    rq = srv.submit("h1", "query", y=y, seed=13)
    st = srv.tick()
    # the hash-state layouts are data-dependent: h1/h2 stack into one
    # sample group only when their bucket counts coincide (2 groups),
    # otherwise they serve in separate groups (3) -- both are correct
    assert st["failed"] == 0 and st["groups"] in (2, 3)
    for r, name, s in ((r1, "h1", src), (r2, "h2", src + 8)):
        nbr = srv.tenant(name).admit()
        nb0, p0, _, _ = _ops.fused_sample(
            nbr.x, nbr.x_sq, jnp.asarray(s, jnp.int32),
            jax.random.PRNGKey(r.seed), hstate=nbr._hstate, **nbr._cfg)
        np.testing.assert_array_equal(r.result[0], np.asarray(nb0))
    hq = srv.tenant("h1").admit().hash_estimator
    e0, _, _ = _hops.hashed_query(srv.tenant("h1").admit().x, jnp.asarray(y),
                                  hq.state, jax.random.PRNGKey(13),
                                  **hq._cfg)
    np.testing.assert_array_equal(rq.result, np.asarray(e0))


# ------------------------------------------------------------------- #
# distributional parity at non-bucket widths (padded lanes)
# ------------------------------------------------------------------- #
def _tv_parity(level1, label, alpha=1e-3):
    """Empirical TV between served draws (padded: width 100 -> bucket 128)
    and sequential draws from one source, against the stats.py tolerance."""
    srv = KernelGraphServable()
    srv.add_tenant("t", _data(label), gaussian(1.0), level1=level1,
                   block_size=16, seed=9)
    nbr = srv.tenant("t").admit()
    cap = srv.dataset("t").capacity
    u0, w, reps = 7, 100, 8
    src = np.full(w, u0)
    h_srv = np.zeros(cap)
    h_seq = np.zeros(cap)
    for i in range(reps):
        r = srv.submit("t", "sample", src=src,
                       seed=stats.derive_seed(label, "srv", i))
        srv.tick()
        assert r.error is None
        h_srv += np.bincount(r.result[0], minlength=cap)
        nb, _, _, _ = _ops.fused_sample(
            nbr.x, nbr.x_sq, jnp.asarray(src, jnp.int32),
            jax.random.PRNGKey(stats.derive_seed(label, "seq", i)),
            hstate=nbr._hstate, **nbr._cfg)
        h_seq += np.bincount(np.asarray(nb), minlength=cap)
    tv = stats.tv_distance(h_srv, h_seq)
    tol = stats.tv_tolerance(cap, w * reps, alpha=alpha)
    assert tv < tol, (tv, tol)


def test_sample_tv_parity_stratified_padded():
    """Padded stratified draws are distribution-identical to sequential
    ones (alpha = 1e-3 documented in tests/stats.py)."""
    _tv_parity("blocked", "tv-blocked")


def test_sample_tv_parity_hash_padded():
    """Padded hashed-level-1 draws are distribution-identical to
    sequential ones."""
    _tv_parity("hash", "tv-hash")


def test_padding_non_bucket_widths_share_group(srv2):
    """Requests of widths 10 and 13 pad to the same 16-bucket, ride one
    group, and return exactly their own lanes."""
    ra = srv2.submit("a", "sample", src=np.arange(10), seed=301)
    rb = srv2.submit("b", "sample", src=np.arange(13), seed=302)
    st = srv2.tick()
    assert st["groups"] == 1
    assert ra.result[0].shape == (10,) and rb.result[0].shape == (13,)
    assert np.isfinite(ra.result[1]).all() and np.isfinite(rb.result[1]).all()
    assert shape_bucket(10) == shape_bucket(13) == 16
    assert shape_bucket(DEFAULT_BUCKETS[-1] + 1) == 512


# ------------------------------------------------------------------- #
# tenant lifecycle + guards
# ------------------------------------------------------------------- #
def test_lru_admission_eviction_readmission():
    """max_resident=1: serving tenant b evicts a's device state; a's next
    request transparently rebuilds (builds counter) and still serves."""
    srv = KernelGraphServable(max_resident=1)
    srv.add_tenant("a", _data("lru-a"), gaussian(1.0), block_size=16)
    srv.add_tenant("b", _data("lru-b"), gaussian(1.0), block_size=16)
    srv.submit("a", "sample", src=np.arange(8), seed=1)
    srv.tick()
    assert srv.tenant("a").resident and not srv.tenant("b").resident
    srv.submit("b", "sample", src=np.arange(8), seed=2)
    srv.tick()
    assert not srv.tenant("a").resident and srv.tenant("b").resident
    assert srv.evictions == 1
    r = srv.submit("a", "sample", src=np.arange(8), seed=3)
    srv.tick()
    assert r.error is None and srv.tenant("a").builds == 2
    assert srv.report()["admissions"] == 3


def test_epoch_stale_isolated_per_request(monkeypatch):
    """REPRO_CHECKS=1: a request whose frontier row died gets ITS OWN
    EstimationError (EPOCH_STALE); the co-submitted healthy request on the
    same tenant is served normally."""
    monkeypatch.setenv("REPRO_CHECKS", "1")
    srv = KernelGraphServable()
    srv.add_tenant("t", _data("stale"), gaussian(1.0), block_size=16)
    srv.dataset("t").delete_rows(np.array([5]))
    bad = srv.submit("t", "sample", src=np.array([4, 5, 6, 7]), seed=1)
    ok = srv.submit("t", "sample", src=np.array([10, 11, 12, 13]), seed=2)
    st = srv.tick()
    assert st["stale"] == 1 and st["failed"] == 1 and st["served"] == 1
    assert bad.error is not None and "EPOCH_STALE" in str(bad.error)
    assert bad.result is None
    assert ok.error is None and np.isfinite(ok.result[1]).all()
    assert srv.dataset("t").is_live(ok.result[0])


def test_stale_flag_advisory_when_checks_off(monkeypatch):
    """Checks off: the stale request is still served, carrying the
    EPOCH_STALE bit on its own status word only."""
    monkeypatch.delenv("REPRO_CHECKS", raising=False)
    from repro.ft import guards as g
    srv = KernelGraphServable()
    srv.add_tenant("t", _data("stale2"), gaussian(1.0), block_size=16)
    srv.dataset("t").delete_rows(np.array([3]))
    bad = srv.submit("t", "sample", src=np.array([3, 8, 9, 10]), seed=1)
    ok = srv.submit("t", "sample", src=np.array([20, 21, 22, 23]), seed=2)
    srv.tick()
    assert bad.error is None and bad.result is not None
    assert bad.status & g.EPOCH_STALE
    assert not (ok.status & g.EPOCH_STALE)


def test_no_retrace_across_ticks(srv2):
    """Second tick at already-seen group shapes compiles nothing new."""
    src = np.arange(16)
    srv2.submit("a", "sample", src=src, seed=41)
    srv2.submit("b", "walk", starts=np.arange(8), length=5, seed=42)
    srv2.tick()
    before = dict(_ops.TRACE_COUNTS)
    srv2.submit("a", "sample", src=src + 1, seed=43)
    srv2.submit("b", "walk", starts=np.arange(8) + 1, length=5, seed=44)
    st = srv2.tick()
    assert st["failed"] == 0
    assert dict(_ops.TRACE_COUNTS) == before


def test_prob_of_width_mismatch_rejected_at_submit(srv2):
    """len(src) != len(dst) is a caller error surfaced at submit() --
    the malformed request never reaches (or poisons) a tick."""
    with pytest.raises(ValueError, match="widths differ"):
        srv2.submit("a", "prob_of", src=np.arange(4), dst=np.arange(5))
    assert srv2.pending() == 0


def test_group_failure_isolated_per_request(srv2):
    """Per-group fault isolation: a group that blows up on device (query
    points with the wrong feature dimension) attaches the exception to
    ITS requests only -- the healthy group of the same tick still serves
    and tick() itself never raises."""
    bad = srv2.submit("a", "query", y=np.zeros((4, D + 3), np.float32),
                      seed=881)
    ok = srv2.submit("b", "sample", src=np.arange(8), seed=882)
    st = srv2.tick()
    assert st["failed"] == 1 and st["served"] == 1
    assert bad.error is not None and bad.result is None and bad.done
    assert ok.error is None and np.isfinite(ok.result[1]).all()


def test_malformed_payload_isolated_per_request(srv2):
    """A request whose payload breaks grouping (walk without length)
    fails alone; the co-submitted request is served."""
    bad = srv2.submit("a", "walk", starts=np.arange(8), seed=883)
    ok = srv2.submit("a", "sample", src=np.arange(8), seed=884)
    st = srv2.tick()
    assert st["failed"] == 1 and st["served"] == 1
    assert isinstance(bad.error, KeyError) and bad.done
    assert ok.error is None


def test_different_feature_dims_do_not_share_group():
    """Tenants with identical static config but different feature
    dimension d carry d in their signature, so they form SEPARATE groups
    (stacking their arenas would be a shape error) and both serve."""
    srv = KernelGraphServable()
    srv.add_tenant("d4", _data("d4"), gaussian(1.0), block_size=16)
    rng = np.random.default_rng(stats.derive_seed("serving", "d6"))
    srv.add_tenant("d6", rng.normal(0, 0.6, (N, 6)).astype(np.float32),
                   gaussian(1.0), block_size=16)
    ra = srv.submit("d4", "sample", src=np.arange(8), seed=871)
    rb = srv.submit("d6", "sample", src=np.arange(8), seed=872)
    st = srv.tick()
    assert st["groups"] == 2 and st["failed"] == 0
    assert ra.error is None and rb.error is None


def test_mutation_between_ticks_refreshes_arena():
    """Mutating a tenant's dataset between ticks invalidates the stacked
    arena via the epoch key: post-mutation draws land on live rows."""
    srv = KernelGraphServable()
    srv.add_tenant("t", _data("mut"), gaussian(1.0), block_size=16)
    srv.submit("t", "sample", src=np.arange(8), seed=1)
    srv.tick()
    ds = srv.dataset("t")
    ds.delete_rows(np.arange(32, 64))
    r = srv.submit("t", "sample", src=np.arange(8), seed=2)
    st = srv.tick()
    assert st["failed"] == 0
    assert ds.is_live(r.result[0]), "sampled a deleted row"


# ------------------------------------------------------------------- #
# serve CLI: graph-stream backfill + multi-tenant path
# ------------------------------------------------------------------- #
def _metrics(capsys):
    out = capsys.readouterr().out
    line = [l for l in out.splitlines() if l.startswith("[serve] metrics ")]
    assert len(line) == 1, out
    return json.loads(line[0][len("[serve] metrics "):])


def test_serve_cli_graph_stream_clean_exit(capsys, monkeypatch):
    """`serve --graph-stream` over a random trace: exit 0 and a parsable
    metrics line with per-tick latencies and zero flags."""
    monkeypatch.delenv("REPRO_CHECKS", raising=False)
    from repro.launch.serve import main
    rc = main(["--graph-stream", "192", "--ticks", "2",
               "--mutate-frac", "0.02"])
    m = _metrics(capsys)
    assert rc == 0 and m["error"] is None
    assert m["mode"] == "graph-stream" and m["ticks"] == 2
    assert m["mutation_ms_per_tick"] > 0 and m["query_ms_per_tick"] > 0
    assert m["flags"] == [] and m["live"] == 192


def test_serve_cli_graph_stream_epoch_stale_exit3(capsys, monkeypatch):
    """Scripted trace: tick 2 deletes tick 1's (reused) query frontier;
    under REPRO_CHECKS=1 the consumer-side EPOCH_STALE check promotes to
    an EstimationError -> exit 3, recorded in the metrics line."""
    monkeypatch.setenv("REPRO_CHECKS", "1")
    import argparse

    from repro.launch.serve import run_graph_stream
    rng = np.random.default_rng(stats.derive_seed("serving", "cli-stale"))
    args = argparse.Namespace(graph_stream=192, ticks=3, mutate_frac=0.02,
                              level1="blocked", seed=0, reuse_frontier=True)
    trace = [dict(insert=rng.normal(size=(4, 16)).astype(np.float32)),
             dict(delete="frontier"), dict()]
    rc = run_graph_stream(args, trace=trace)
    m = _metrics(capsys)
    assert rc == 3
    assert "EPOCH_STALE" in (m["error"] or "")
    assert m["ticks"] < m["ticks_planned"]


def test_serve_cli_multi_tenant_metrics(capsys, monkeypatch):
    """`serve --serve-tenants`: mixed-op batched ticks end-to-end, p50/p99
    latency and throughput in the metrics line, exit 0."""
    monkeypatch.delenv("REPRO_CHECKS", raising=False)
    from repro.launch.serve import main
    rc = main(["--serve-tenants", "2", "--requests", "16", "--ticks", "2",
               "--max-resident", "2"])
    m = _metrics(capsys)
    assert rc == 0 and m["mode"] == "multi-tenant"
    assert m["served"] == 32 and m["failed"] == 0
    assert m["p50_ms"] > 0 and m["p99_ms"] >= m["p50_ms"]
    assert m["throughput_rps"] > 0


# ------------------------------------------------------------------- #
# 8 simulated devices: the batching layer adds zero extra collectives
# ------------------------------------------------------------------- #
def test_mesh_serving_one_psum_subprocess():
    """A mesh tenant's served draw batch (4 concatenated requests) is ONE
    engine program with exactly one psum and zero ppermute -- the §9
    schedule survives the batching layer -- and its per-request slices are
    bitwise the direct engine call under the documented group key stream
    (first seed -> PRNGKey, co-batched seeds folded in).  A second tick
    exercises every other mesh op -- walk, query, and prob_of (served
    alone: bitwise the direct masked_block_sums + prob_of read)."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.serving import KernelGraphServable
from repro.kernels.kde_sampler.sharded import collective_counts
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(%d)
x = rng.normal(0, 0.6, (192, 4)).astype(np.float32)
srv = KernelGraphServable()
srv.add_tenant("m", x, gaussian(1.0), block_size=16, mesh=mesh)
reqs = [srv.submit("m", "sample", src=np.arange(16) + 16 * i, seed=900 + i)
        for i in range(4)]
st = srv.tick()
assert st["failed"] == 0 and st["groups"] == 1, st
eng = srv.tenant("m").admit()._engine
cat = jnp.asarray(np.concatenate([np.arange(16) + 16 * i
                                  for i in range(4)]), jnp.int32)
key = jax.random.PRNGKey(reqs[0].seed)
for r in reqs[1:]:
    key = jax.random.fold_in(key, r.seed)
cc = collective_counts(lambda s, k: eng.fused_sample(s, k), cat, key)
assert cc["psum_total"] == 1 and cc["ppermute_total"] == 0, cc
nb, prob, _, _ = eng.fused_sample(cat, key)
nb, prob = np.asarray(nb), np.asarray(prob)
for i, r in enumerate(reqs):
    np.testing.assert_array_equal(r.result[0], nb[16 * i:16 * (i + 1)])
    np.testing.assert_array_equal(r.result[1], prob[16 * i:16 * (i + 1)])
rw = srv.submit("m", "walk", starts=np.arange(8), length=3, seed=950)
rq = srv.submit("m", "query", y=rng.normal(0, 0.6, (6, 4)).astype(np.float32))
src_p, dst_p = np.arange(8), np.arange(8) + 24
rp = srv.submit("m", "prob_of", src=src_p, dst=dst_p, seed=960)
st2 = srv.tick()
assert st2["failed"] == 0, [str(r.error) for r in (rw, rq, rp)]
assert rw.result[0].shape == (8,)
assert np.isfinite(rq.result).all() and rq.result.shape == (6,)
assert rp.error is None and rp.status == 0
bs, _ = eng.masked_block_sums(jnp.asarray(src_p, jnp.int32),
                              jax.random.PRNGKey(rp.seed))
p0, _ = eng.prob_of_from_block_sums(jnp.asarray(src_p, jnp.int32),
                                    jnp.asarray(dst_p, jnp.int32), bs)
np.testing.assert_array_equal(rp.result, np.asarray(p0))
assert np.isfinite(rp.result).all() and (rp.result > 0).all()
print("MESH_SERVE_OK")
""" % stats.derive_seed("serving", "mesh")
    full = ('import os\nos.environ["XLA_FLAGS"] = '
            '"--xla_force_host_platform_device_count=8"\n'
            'import sys; sys.path.insert(0, "src")\n' + code)
    p = subprocess.run([sys.executable, "-c", full], capture_output=True,
                       text=True, cwd=".")
    assert p.returncode == 0, p.stderr[-1500:]
    assert "MESH_SERVE_OK" in p.stdout
