"""Distribution layer: the sharded sampling engine (DESIGN.md §9 -- ref
oracles, collective schedule, distribution equivalence, pipeline counter
audits), sharded KDE wrappers, sharding rules, small-mesh dry-run
(subprocesses own their XLA_FLAGS -- the main test process stays 1-device)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import get_config


def _run(code: str, devices: int = 8) -> str:
    full = (f'import os\nos.environ["XLA_FLAGS"] = '
            f'"--xla_force_host_platform_device_count={devices}"\n'
            f'import sys; sys.path.insert(0, "src")\n' + code)
    p = subprocess.run([sys.executable, "-c", full], capture_output=True,
                       text=True, cwd=".")
    assert p.returncode == 0, p.stderr[-1200:]
    return p.stdout


def test_sharded_kde_query_matches_local():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.kde.distributed import sharded_kde_query, make_sharded_dataset, degree_preprocessing
ker = gaussian(1.0)
rng = np.random.default_rng(0)
x = rng.normal(0, 0.6, (256, 5)).astype(np.float32)
y = rng.normal(0, 0.6, (16, 5)).astype(np.float32)
mesh = jax.make_mesh((4, 2), ("data", "model"))
xs = make_sharded_dataset(mesh, x)
q = sharded_kde_query(mesh, ker)
got = np.asarray(q(jnp.asarray(y), xs))
want = np.asarray(ker.pairwise(jnp.asarray(y), jnp.asarray(x)).sum(1))
np.testing.assert_allclose(got, want, rtol=1e-4)
deg = degree_preprocessing(mesh, ker)
dg = np.asarray(deg(xs))
wantd = np.asarray(ker.matrix(jnp.asarray(x)).sum(1)) - 1.0
np.testing.assert_allclose(dg, wantd, rtol=1e-3, atol=1e-3)
print("SHARDED_KDE_OK")
""")
    assert "SHARDED_KDE_OK" in out


def test_degree_preprocessing_multi_axis_mesh():
    """Regression: the ring permutation in degree_preprocessing must run
    over the *flattened* index of all data axes.  On a ("pod", "data") =
    (4, 2) mesh the old ring covered axis_size(axes[0]) = 4 of 8 shards and
    silently dropped half the dataset's contributions."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.kde.distributed import degree_preprocessing, make_sharded_dataset
ker = gaussian(1.0)
rng = np.random.default_rng(0)
x = rng.normal(0, 0.6, (256, 5)).astype(np.float32)
mesh = jax.make_mesh((4, 2), ("pod", "data"))
xs = make_sharded_dataset(mesh, x, data_axes=("pod", "data"))
deg = degree_preprocessing(mesh, ker, data_axes=("pod", "data"))
got = np.asarray(deg(xs))
want = np.asarray(ker.matrix(jnp.asarray(x)).sum(1)) - 1.0
np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
print("MULTIAXIS_DEG_OK")
""")
    assert "MULTIAXIS_DEG_OK" in out


def test_sharded_block_sums():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.kde.distributed import sharded_block_sums, make_sharded_dataset
ker = gaussian(1.0)
rng = np.random.default_rng(0)
x = rng.normal(0, 0.6, (256, 5)).astype(np.float32)
y = rng.normal(0, 0.6, (8, 5)).astype(np.float32)
mesh = jax.make_mesh((4,), ("data",))
xs = make_sharded_dataset(mesh, x)
f = sharded_block_sums(mesh, ker, num_blocks_per_shard=4)
got = np.asarray(f(jnp.asarray(y), xs))       # (8, 16)
kv = np.asarray(ker.pairwise(jnp.asarray(y), jnp.asarray(x)))
want = kv.reshape(8, 16, 16).sum(-1)
np.testing.assert_allclose(got, want, rtol=1e-4)
print("BLOCKSUMS_OK")
""")
    assert "BLOCKSUMS_OK" in out


def test_sharded_block_sums_ragged_shard_regression():
    """Regression: a shard size not divisible by the block count used to
    crash the in-body reshape.  Now the shard is padded with the sentinel
    rows (kernel values exactly 0), so tail blocks sum only their real
    rows -- checked against a host oracle of the same layout."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.kde.distributed import sharded_block_sums, make_sharded_dataset
ker = gaussian(1.0)
rng = np.random.default_rng(0)
x = rng.normal(0, 0.6, (256, 5)).astype(np.float32)   # shard = 64 rows
y = rng.normal(0, 0.6, (6, 5)).astype(np.float32)
mesh = jax.make_mesh((4,), ("data",))
xs = make_sharded_dataset(mesh, x)
f = sharded_block_sums(mesh, ker, num_blocks_per_shard=5)  # 64 % 5 != 0
got = np.asarray(f(jnp.asarray(y), xs))               # (6, 20)
kv = np.asarray(ker.pairwise(jnp.asarray(y), jnp.asarray(x)))
want = np.zeros((6, 20))
for p in range(4):                                    # bs_l = ceil(64/5) = 13
    for b in range(5):
        lo = p * 64 + b * 13
        hi = min(p * 64 + min((b + 1) * 13, 64), 256)
        if lo < hi:
            want[:, p * 5 + b] = kv[:, lo:hi].sum(1)
np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
print("RAGGED_OK")
""")
    assert "RAGGED_OK" in out


def test_sharded_block_sums_section2_contract_bitwise():
    """With ``own=`` the distributed level-1 read applies the §2 sampling
    contract (self-block correction, 1e-12 floor) and must agree bitwise
    with the single-device ``ops.masked_block_sums`` on aligned layouts."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.kde.distributed import sharded_block_sums, make_sharded_dataset
from repro.kernels.kde_sampler import ops as sops
ker = gaussian(1.0)
rng = np.random.default_rng(0)
n, bs = 256, 16
x = rng.normal(0, 0.6, (n, 5)).astype(np.float32)
src = rng.integers(0, n, 24).astype(np.int32)
mesh = jax.make_mesh((8,), ("data",))
xs = make_sharded_dataset(mesh, x)
f = sharded_block_sums(mesh, ker, num_blocks_per_shard=2)   # 32/2 = bs 16
got = np.asarray(f(jnp.asarray(x[src]), xs, own=src // bs))
xd = jnp.asarray(x)
want = np.asarray(sops.masked_block_sums(
    xd, jnp.sum(xd * xd, -1), jnp.asarray(src), jax.random.PRNGKey(0),
    kind="gaussian", inv_bw=1.0, beta=1.0, pairwise=None, block_size=bs,
    num_blocks=n // bs, n=n, s=16, exact=True)[0])
np.testing.assert_array_equal(got, want)
print("CONTRACT_BITWISE_OK")
""")
    assert "CONTRACT_BITWISE_OK" in out


def test_sharded_engine_oracle_schedule_and_no_retrace():
    """The ShardedBlocks engine: (a) draws/walks reproduce the ref.py
    oracles bit-for-bit on both level-1 paths, (b) the collective schedule
    is exactly one psum and zero ppermute per draw batch (jaxpr-counted),
    (c) repeated calls never retrace, (d) the level-1 read agrees bitwise
    with the single-device engine."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.kernels.kde_sampler.sharded import ShardedBlocks, collective_counts
from repro.kernels.kde_sampler import ref as sref, ops as sops
ker = gaussian(1.0)
rng = np.random.default_rng(0)
n, d, bsz = 250, 5, 16
x = rng.normal(0, 0.6, (n, d)).astype(np.float32)
mesh = jax.make_mesh((8,), ("data",))
key = jax.random.PRNGKey(3)
src = jnp.asarray(rng.integers(0, n, 64), jnp.int32)
for exact in (True, False):
    eng = ShardedBlocks(mesh, x, ker, block_size=bsz, exact=exact,
                        samples_per_block=8)
    nb, prob, sums, st = eng.fused_sample(src, key)
    assert int(np.asarray(st)[0]) == 0, st
    rnb, rprob, rsums = sref.sharded_fused_sample_ref(
        eng.x_rep, eng.x_sq_rep, src, key, "gaussian", 1.0, 1.0, bsz,
        eng.blocks_per_shard, eng.num_shards, n, exact=exact, s=8)
    np.testing.assert_array_equal(np.asarray(nb), np.asarray(rnb))
    np.testing.assert_allclose(np.asarray(prob), np.asarray(rprob),
                               rtol=2e-5, atol=1e-9)
    np.testing.assert_array_equal(np.asarray(sums), np.asarray(rsums))
eng = ShardedBlocks(mesh, x, ker, block_size=bsz, exact=True)
keys = jax.random.split(jax.random.PRNGKey(7), 5)
end, _, wst, wfb = eng.walk_scan(src, keys)
assert int(np.asarray(wst)[0]) == 0 and int(np.asarray(wfb)) == 0
rend = sref.sharded_walk_ref(eng.x_rep, eng.x_sq_rep, src, keys, "gaussian",
                             1.0, 1.0, bsz, eng.blocks_per_shard,
                             eng.num_shards, n, exact=True)
np.testing.assert_array_equal(np.asarray(end), np.asarray(rend))
# bitwise vs the single-device level-1 read (real blocks; pads are 0)
xd = jnp.asarray(x)
sd = np.asarray(sops.masked_block_sums(
    xd, jnp.sum(xd * xd, -1), src, key, kind="gaussian", inv_bw=1.0,
    beta=1.0, pairwise=None, block_size=bsz, num_blocks=-(-n // bsz), n=n,
    s=16, exact=True)[0])
sums = np.asarray(eng.masked_block_sums(src, key)[0])
np.testing.assert_array_equal(sums[:, :sd.shape[1]], sd)
assert np.all(sums[:, sd.shape[1]:] == 0.0)
# collective schedule: one psum, no ppermute, per draw batch
degs = (np.asarray(ker.matrix(xd), np.float64).sum(1) - 1).astype(np.float32)
cdf = (np.cumsum(degs) / degs.sum()).astype(np.float32)
ekeys = jax.random.split(jax.random.PRNGKey(1), 3)
u = src[:40]; v = (src[:40] + 7) % n
for name, cc in [
    ("walk", collective_counts(lambda s, k: eng.walk_scan(s, k), src, keys)),
    ("edges", collective_counts(
        lambda c, dg, ks: eng.edge_batch_scan(c, dg, 1.0 / degs.sum(),
                                              1.0 / 300, ks, batch=64),
        cdf, degs, ekeys)),
    ("tri", collective_counts(
        lambda a, b, dg, ks: eng.triangle_edge_scan(a, b, dg, ks),
        u, v, degs, ekeys)),
    ("draw", collective_counts(lambda s, k: eng.fused_sample(s, k), src,
                               key)),
]:
    assert cc["psum_total"] == 1 and cc["ppermute_total"] == 0, (name, cc)
# noisy power: one psum per iteration (scan body) + one final exact matvec
from repro.kernels.kde_sampler.sharded import sharded_noisy_power
ksub = jnp.asarray(np.asarray(ker.matrix(xd[:96, :]), np.float32))
v0 = jnp.ones(96, jnp.float32) / jnp.sqrt(96.0)
nkeys = jax.random.split(jax.random.PRNGKey(4), 6)
cc = collective_counts(lambda kk: sharded_noisy_power(
    mesh, ksub, v0, kk, num_samples=16), nkeys)
assert cc["psum_total"] == 2 and cc["ppermute_total"] == 0, cc
# no-retrace
eng.fused_sample(src, key); eng.walk_scan(src, keys)
before = dict(sops.TRACE_COUNTS)
for _ in range(3):
    eng.fused_sample(src, key); eng.walk_scan(src, keys)
assert dict(sops.TRACE_COUNTS) == before
print("ENGINE_OK")
""")
    assert "ENGINE_OK" in out


def test_sharded_draw_distribution_equivalence_ks():
    """The two-stage collective draw samples the same law as the flat
    single-device draw: one-sample KS against the exact conditional
    k(u, .)/deg(u) for both engines, and a two-sample KS between them.
    Seeds derive from ``stats.ROOT_SEED`` and the thresholds are the
    precomputed ``stats.ks_critical`` values at alpha = 1e-4 (the
    false-positive budget documented in tests/stats.py; at m = 4096 the
    one-sample critical value is 0.0348, matching the old ad-hoc
    2.2/sqrt(m) = 0.0344 in strictness)."""
    import stats
    data_seed = stats.derive_seed("distributed", "ks", "data")
    engine_seed = stats.derive_seed("distributed", "ks", "engine")
    crit1 = stats.ks_critical(4096, alpha=1e-4)
    crit2 = stats.ks_critical(4096, 4096, alpha=1e-4)
    out = _run(f"""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.sampling.edge import NeighborSampler
ker = gaussian(1.0)
rng = np.random.default_rng({data_seed})
n, m, u0 = 512, 4096, 17
x = rng.normal(0, 0.5, (n, 6)).astype(np.float32)
mesh = jax.make_mesh((8,), ("data",))
k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
p = k[u0].copy(); p[u0] = 0.0; p /= p.sum()
cdf = np.cumsum(p)
src = np.full(m, u0, np.int64)
def ecdf_D(samples):
    counts = np.bincount(samples, minlength=n)
    return np.abs(np.cumsum(counts) / len(samples) - cdf).max()
nb_s, _ = NeighborSampler(x, ker, exact_blocks=True, seed={engine_seed},
                          mesh=mesh).sample(src)
nb_1, _ = NeighborSampler(x, ker, exact_blocks=True,
                          seed={engine_seed}).sample(src)
D_s, D_1 = ecdf_D(nb_s), ecdf_D(nb_1)
assert D_s < {crit1!r} and D_1 < {crit1!r}, (D_s, D_1, {crit1!r})
c2 = np.bincount(nb_s, minlength=n), np.bincount(nb_1, minlength=n)
D_2 = np.abs(np.cumsum(c2[0]) / m - np.cumsum(c2[1]) / m).max()
assert D_2 < {crit2!r}, (D_2, {crit2!r})
print("KS_OK", D_s, D_1, D_2)
""")
    assert "KS_OK" in out


def test_sharded_pipelines_counters_and_accuracy():
    """Every mesh=-enabled Table-1 pipeline (sparsify, arboricity,
    triangles, LRA, eigen, walks via spectrum) matches the single-device
    eval counters EXACTLY and stays within the single-device accuracy
    envelope on a simulated 8-device mesh."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.sparsify import spectral_sparsify
from repro.core.graph.arboricity import estimate_arboricity, exact_arboricity
from repro.core.graph.triangles import estimate_triangle_weight, exact_triangle_weight
from repro.core.lowrank import fkv_lowrank, projection_error, optimal_error
from repro.core.eigen import top_eigenvalue
from repro.core.spectrum import approximate_spectrum
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
x = rng.normal(0, 0.35, (300, 5)).astype(np.float32)
ker = gaussian(2.0)
k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)

g1 = spectral_sparsify(x, ker, 3000, estimator="exact", exact_blocks=True, seed=0)
g2 = spectral_sparsify(x, ker, 3000, estimator="exact", exact_blocks=True, seed=0, mesh=mesh)
assert (g1.kernel_evals, g1.kde_queries) == (g2.kernel_evals, g2.kde_queries)
lt = np.diag(k.sum(1) - 1) - (k - np.eye(300))
err = np.linalg.norm(g2.laplacian_dense() - lt) / np.linalg.norm(lt)
assert err < 0.5, err
g1s = spectral_sparsify(x, ker, 3000, seed=0)
g2s = spectral_sparsify(x, ker, 3000, seed=0, mesh=mesh)
assert g1s.kernel_evals == g2s.kernel_evals    # stratified counters too

a1 = estimate_arboricity(x, ker, 4000, estimator="exact", seed=0)
a2 = estimate_arboricity(x, ker, 4000, estimator="exact", seed=0, mesh=mesh)
tr = exact_arboricity(ker, x)
assert a1.kernel_evals == a2.kernel_evals and abs(a2.density - tr) / tr < 0.15

t1 = estimate_triangle_weight(x, ker, 300, 16, estimator="exact", seed=0)
t2 = estimate_triangle_weight(x, ker, 300, 16, estimator="exact", seed=0, mesh=mesh)
tt = exact_triangle_weight(ker, x)
assert t1.kernel_evals == t2.kernel_evals and abs(t2.total_weight - tt) / tt < 0.3

r1 = fkv_lowrank(x, ker, rank=6, num_rows=120, seed=0)
r2 = fkv_lowrank(x, ker, rank=6, num_rows=120, seed=0, mesh=mesh)
assert r1.kernel_evals == r2.kernel_evals
assert projection_error(k, r2.u) < optimal_error(k, 6) + 0.02 * np.linalg.norm(k) ** 2

e1 = top_eigenvalue(x, ker, t=150, method="noisy_power", seed=0)
e2 = top_eigenvalue(x, ker, t=150, method="noisy_power", seed=0, mesh=mesh)
assert e1.kernel_evals == e2.kernel_evals
assert abs(e2.eigenvalue - e1.eigenvalue) / abs(e1.eigenvalue) < 1e-3

sp1 = approximate_spectrum(x, ker, length=5, num_sources=6, walks_per_source=8, seed=0)
sp2 = approximate_spectrum(x, ker, length=5, num_sources=6, walks_per_source=8, seed=0, mesh=mesh)
assert sp1.kernel_evals == sp2.kernel_evals
print("PIPELINES_OK")
""")
    assert "PIPELINES_OK" in out


def test_param_sharding_rules():
    """Divisibility fallbacks: granite vocab, yi kv heads."""
    out = _run("""
import jax, jax.numpy as jnp
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.distributed import sharding as shard
mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch in ("yi_6b", "granite_3_2b", "qwen3_moe_235b_a22b"):
    cfg = get_config(arch)
    ps = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    flat = jax.tree_util.tree_flatten_with_path(ps)[0]
    for path, leaf in flat:
        spec = shard.param_spec(path, leaf, mesh)
        # every sharded dim must divide
        for dim, entry in enumerate(spec):
            if entry is None: continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes: size *= mesh.shape[a]
            assert leaf.shape[dim] % size == 0, (arch, path, leaf.shape, spec)
print("RULES_OK")
""")
    assert "RULES_OK" in out


@pytest.mark.parametrize("arch", ["yi_6b", "rwkv6_3b", "granite_moe_1b_a400m"])
def test_small_mesh_dryrun_train_and_decode(arch):
    """Reduced-config lower+compile on a (2,2,2) pod mesh -- the same code
    path as the production dry-run."""
    out = _run(f"""
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_reduced, ShapeConfig
from repro.data.pipeline import input_specs, token_split
from repro.distributed import sharding as shard
from repro.models import transformer as T
from repro.models.layers import activation_sharding
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step, make_decode_step
from repro.roofline.analysis import collective_bytes

cfg = get_reduced("{arch}")
shape = ShapeConfig("t", 64, 8, "train")
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
params_s = jax.eval_shape(lambda: T.cast_params(T.init_params(jax.random.PRNGKey(0), cfg), jnp.bfloat16))
p_sh = shard.param_shardings(params_s, mesh)
specs = input_specs(cfg, shape)
b_sh = {{k: NamedSharding(mesh, shard.batch_spec(mesh, v.ndim, v.shape[0])) for k, v in specs.items()}}
o_s = jax.eval_shape(opt.init_adamw, params_s)
o_sh = opt.AdamWState(step=NamedSharding(mesh, P()), m=p_sh, v=jax.tree.map(lambda s: s, p_sh))
with activation_sharding(mesh, ("pod", "data")):
    comp = jax.jit(make_train_step(cfg), in_shardings=(p_sh, o_sh, b_sh),
                   out_shardings=(p_sh, o_sh, None)).lower(params_s, o_s, specs).compile()
cs = collective_bytes(comp.as_text(), default_trip=cfg.num_layers)
assert cs.total_bytes > 0
assert comp.memory_analysis().temp_size_in_bytes > 0
print("DRYRUN_OK", cs.count_by_kind)
""")
    assert "DRYRUN_OK" in out
