"""Distribution layer: sharded KDE, sharding rules, small-mesh dry-run
(subprocesses own their XLA_FLAGS -- the main test process stays 1-device)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import get_config


def _run(code: str, devices: int = 8) -> str:
    full = (f'import os\nos.environ["XLA_FLAGS"] = '
            f'"--xla_force_host_platform_device_count={devices}"\n'
            f'import sys; sys.path.insert(0, "src")\n' + code)
    p = subprocess.run([sys.executable, "-c", full], capture_output=True,
                       text=True, cwd=".")
    assert p.returncode == 0, p.stderr[-1200:]
    return p.stdout


def test_sharded_kde_query_matches_local():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.kde.distributed import sharded_kde_query, make_sharded_dataset, degree_preprocessing
ker = gaussian(1.0)
rng = np.random.default_rng(0)
x = rng.normal(0, 0.6, (256, 5)).astype(np.float32)
y = rng.normal(0, 0.6, (16, 5)).astype(np.float32)
mesh = jax.make_mesh((4, 2), ("data", "model"))
xs = make_sharded_dataset(mesh, x)
q = sharded_kde_query(mesh, ker)
got = np.asarray(q(jnp.asarray(y), xs))
want = np.asarray(ker.pairwise(jnp.asarray(y), jnp.asarray(x)).sum(1))
np.testing.assert_allclose(got, want, rtol=1e-4)
deg = degree_preprocessing(mesh, ker)
dg = np.asarray(deg(xs))
wantd = np.asarray(ker.matrix(jnp.asarray(x)).sum(1)) - 1.0
np.testing.assert_allclose(dg, wantd, rtol=1e-3, atol=1e-3)
print("SHARDED_KDE_OK")
""")
    assert "SHARDED_KDE_OK" in out


def test_degree_preprocessing_multi_axis_mesh():
    """Regression: the ring permutation in degree_preprocessing must run
    over the *flattened* index of all data axes.  On a ("pod", "data") =
    (4, 2) mesh the old ring covered axis_size(axes[0]) = 4 of 8 shards and
    silently dropped half the dataset's contributions."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.kde.distributed import degree_preprocessing, make_sharded_dataset
ker = gaussian(1.0)
rng = np.random.default_rng(0)
x = rng.normal(0, 0.6, (256, 5)).astype(np.float32)
mesh = jax.make_mesh((4, 2), ("pod", "data"))
xs = make_sharded_dataset(mesh, x, data_axes=("pod", "data"))
deg = degree_preprocessing(mesh, ker, data_axes=("pod", "data"))
got = np.asarray(deg(xs))
want = np.asarray(ker.matrix(jnp.asarray(x)).sum(1)) - 1.0
np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
print("MULTIAXIS_DEG_OK")
""")
    assert "MULTIAXIS_DEG_OK" in out


def test_sharded_block_sums():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.kernels_fn import gaussian
from repro.core.kde.distributed import sharded_block_sums, make_sharded_dataset
ker = gaussian(1.0)
rng = np.random.default_rng(0)
x = rng.normal(0, 0.6, (256, 5)).astype(np.float32)
y = rng.normal(0, 0.6, (8, 5)).astype(np.float32)
mesh = jax.make_mesh((4,), ("data",))
xs = make_sharded_dataset(mesh, x)
f = sharded_block_sums(mesh, ker, num_blocks_per_shard=4)
got = np.asarray(f(jnp.asarray(y), xs))       # (8, 16)
kv = np.asarray(ker.pairwise(jnp.asarray(y), jnp.asarray(x)))
want = kv.reshape(8, 16, 16).sum(-1)
np.testing.assert_allclose(got, want, rtol=1e-4)
print("BLOCKSUMS_OK")
""")
    assert "BLOCKSUMS_OK" in out


def test_param_sharding_rules():
    """Divisibility fallbacks: granite vocab, yi kv heads."""
    out = _run("""
import jax, jax.numpy as jnp
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.distributed import sharding as shard
mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch in ("yi_6b", "granite_3_2b", "qwen3_moe_235b_a22b"):
    cfg = get_config(arch)
    ps = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    flat = jax.tree_util.tree_flatten_with_path(ps)[0]
    for path, leaf in flat:
        spec = shard.param_spec(path, leaf, mesh)
        # every sharded dim must divide
        for dim, entry in enumerate(spec):
            if entry is None: continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes: size *= mesh.shape[a]
            assert leaf.shape[dim] % size == 0, (arch, path, leaf.shape, spec)
print("RULES_OK")
""")
    assert "RULES_OK" in out


@pytest.mark.parametrize("arch", ["yi_6b", "rwkv6_3b", "granite_moe_1b_a400m"])
def test_small_mesh_dryrun_train_and_decode(arch):
    """Reduced-config lower+compile on a (2,2,2) pod mesh -- the same code
    path as the production dry-run."""
    out = _run(f"""
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_reduced, ShapeConfig
from repro.data.pipeline import input_specs, token_split
from repro.distributed import sharding as shard
from repro.models import transformer as T
from repro.models.layers import activation_sharding
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step, make_decode_step
from repro.roofline.analysis import collective_bytes

cfg = get_reduced("{arch}")
shape = ShapeConfig("t", 64, 8, "train")
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
params_s = jax.eval_shape(lambda: T.cast_params(T.init_params(jax.random.PRNGKey(0), cfg), jnp.bfloat16))
p_sh = shard.param_shardings(params_s, mesh)
specs = input_specs(cfg, shape)
b_sh = {{k: NamedSharding(mesh, shard.batch_spec(mesh, v.ndim, v.shape[0])) for k, v in specs.items()}}
o_s = jax.eval_shape(opt.init_adamw, params_s)
o_sh = opt.AdamWState(step=NamedSharding(mesh, P()), m=p_sh, v=jax.tree.map(lambda s: s, p_sh))
with activation_sharding(mesh, ("pod", "data")):
    comp = jax.jit(make_train_step(cfg), in_shardings=(p_sh, o_sh, b_sh),
                   out_shardings=(p_sh, o_sh, None)).lower(params_s, o_s, specs).compile()
cs = collective_bytes(comp.as_text(), default_trip=cfg.num_layers)
assert cs.total_bytes > 0
assert comp.memory_analysis().temp_size_in_bytes > 0
print("DRYRUN_OK", cs.count_by_kind)
""")
    assert "DRYRUN_OK" in out
