"""Shared pytest configuration for the suite.

Provides a ``--timeout`` fallback when the ``pytest-timeout`` plugin is
not installed (the pinned CI image has it; bare dev environments may
not): a SIGALRM fires after the per-test budget and fails the test with
a ``TimeoutError`` instead of hanging the whole run.  When the real
plugin IS present this file defines nothing -- the plugin owns the
option and its (more capable) enforcement.
"""
from __future__ import annotations

import importlib.util
import signal

import pytest

_HAVE_PLUGIN = importlib.util.find_spec("pytest_timeout") is not None


if not _HAVE_PLUGIN:

    def pytest_addoption(parser):
        """Register ``--timeout`` so CI command lines that assume
        pytest-timeout keep working without the plugin."""
        parser.addoption(
            "--timeout", type=float, default=0, metavar="SECONDS",
            help="per-test wall-clock budget; 0 disables "
                 "(SIGALRM fallback, pytest-timeout not installed)")

    @pytest.hookimpl(wrapper=True)
    def pytest_runtest_call(item):
        """Arm a SIGALRM around each test body; on expiry the test fails
        with TimeoutError rather than wedging the session."""
        budget = item.config.getoption("--timeout")
        if not budget or not hasattr(signal, "SIGALRM"):
            return (yield)

        def _expired(signum, frame):
            raise TimeoutError(
                f"{item.nodeid} exceeded --timeout={budget:g}s "
                f"(SIGALRM fallback)")

        old = signal.signal(signal.SIGALRM, _expired)
        signal.setitimer(signal.ITIMER_REAL, budget)
        try:
            return (yield)
        finally:
            signal.setitimer(signal.ITIMER_REAL, 0)
            signal.signal(signal.SIGALRM, old)
