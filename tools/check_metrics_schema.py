"""CI gate for the observability exporters (DESIGN.md §15.3).

Validates the two machine-readable metric surfaces against the pinned
``repro.obs.export.SCHEMA_VERSION``:

* ``launch/serve.py`` JSON-lines: every ``[serve] metrics {...}`` line in
  the given log file(s) must json-parse and carry the required keys for
  its ``mode`` (``multi-tenant`` / ``graph-stream``).
* ``BENCH_*.json`` artifacts: every artifact must embed a ``telemetry``
  block (``schema_version`` / ``backend`` / ``fenced`` / ``wall_us``) --
  the shared stamp proving the numbers came off a fenced ``obs.Timer``
  path.

Usage::

    PYTHONPATH=src python tools/check_metrics_schema.py [logfile ...]
    PYTHONPATH=src python tools/check_metrics_schema.py --no-bench serve.log

Exit 0 when everything validates; exit 1 with a per-failure listing
otherwise.  Log files are optional (the BENCH sweep alone is a valid
invocation); passing a log file that contains NO metrics line is an
error, because it usually means the prefix drifted.
"""
from __future__ import annotations

import argparse
import glob
import json
import sys

from repro.obs import export


def check_log(path: str, errors: list) -> int:
    """Validate every metrics line in one serve log; returns the count."""
    seen = 0
    with open(path) as fh:
        for ln, line in enumerate(fh, 1):
            if not line.startswith(export.METRICS_PREFIX):
                continue
            seen += 1
            try:
                obj = json.loads(line[len(export.METRICS_PREFIX):])
                export.validate_metrics_line(obj)
            except (ValueError, KeyError) as e:
                errors.append(f"{path}:{ln}: {e}")
    if seen == 0:
        errors.append(f"{path}: no '{export.METRICS_PREFIX.strip()}' line "
                      f"found (prefix drift?)")
    return seen


def check_bench(pattern: str, errors: list) -> int:
    """Validate the telemetry block of every matching BENCH artifact."""
    paths = sorted(glob.glob(pattern))
    for path in paths:
        try:
            blk = json.load(open(path)).get("telemetry")
            if blk is None:
                raise ValueError("no 'telemetry' block")
            export.validate_telemetry_block(blk, path=path)
        except (ValueError, KeyError, json.JSONDecodeError) as e:
            errors.append(f"{path}: {e}")
    return len(paths)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("logs", nargs="*",
                    help="serve.py log files to scan for metrics lines")
    ap.add_argument("--bench-glob", default="BENCH_*.json")
    ap.add_argument("--no-bench", action="store_true",
                    help="skip the BENCH_*.json telemetry sweep")
    args = ap.parse_args(argv)
    errors: list = []
    lines = sum(check_log(p, errors) for p in args.logs)
    artifacts = 0 if args.no_bench else check_bench(args.bench_glob, errors)
    if errors:
        print("\n".join("SCHEMA FAIL " + e for e in errors))
        return 1
    print(f"# metrics schema ok: {lines} serve line(s), "
          f"{artifacts} BENCH artifact(s), "
          f"schema_version={export.SCHEMA_VERSION}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
