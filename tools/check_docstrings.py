"""Docstring presence check for the public core API (pydocstyle-style,
dependency-free) -- the CI guard behind the PR-3 docstring audit.

Rules, applied to every module under ``src/repro/core``:

1. the module has a docstring that cites the paper (an ``Algorithm /
   Theorem / Lemma / Corollary / Definition / Section N`` reference), so
   each file is anchored to what it reproduces;
2. every public module-level function and class has a docstring;
3. every public method of a public class has a docstring (dunders and
   ``_private`` names are exempt; bare ``@property`` wrappers are not).

  PYTHONPATH=src python tools/check_docstrings.py
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

CORE = Path(__file__).resolve().parent.parent / "src" / "repro" / "core"
PAPER_REF = re.compile(
    r"(Algorithm|Theorem|Lemma|Corollary|Definition|Section|§)\s*[0-9]")


def _public(name: str) -> bool:
    return not name.startswith("_")


def _check_def(node, where: str, errors: list, require_ref: bool = False):
    doc = ast.get_docstring(node)
    if not doc:
        errors.append(f"{where}: missing docstring")
    elif require_ref and not PAPER_REF.search(doc):
        errors.append(f"{where}: docstring cites no paper "
                      "Algorithm/Theorem/Section number")


def check_module(path: Path) -> list:
    errors = []
    rel = path.relative_to(CORE.parent.parent.parent)
    tree = ast.parse(path.read_text(), filename=str(path))
    _check_def(tree, f"{rel} (module)", errors, require_ref=True)
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _public(node.name):
                _check_def(node, f"{rel}:{node.lineno} def {node.name}",
                           errors)
        elif isinstance(node, ast.ClassDef) and _public(node.name):
            _check_def(node, f"{rel}:{node.lineno} class {node.name}", errors)
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and _public(sub.name):
                    _check_def(sub, f"{rel}:{sub.lineno} "
                               f"{node.name}.{sub.name}", errors)
    return errors


def main() -> int:
    errors = []
    for path in sorted(CORE.rglob("*.py")):
        if path.name == "__init__.py" and not path.read_text().strip():
            continue
        errors.extend(check_module(path))
    if errors:
        print(f"{len(errors)} docstring violation(s) in src/repro/core:")
        for e in errors:
            print(f"  {e}")
        return 1
    print("docstring check: src/repro/core is clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
