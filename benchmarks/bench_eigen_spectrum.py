"""Theorems 5.22 (top eigenvalue) and 5.17 (EMD spectrum).

derived: eigen -> "rel_err=<e>;kernel_evals=<n>" (both power-method modes);
spectrum -> "emd=<e>;kernel_evals=<n>" vs walk budget.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.eigen import top_eigenvalue, top_eigenvalue_exact
from repro.core.kernels_fn import gaussian
from repro.core.spectrum import approximate_spectrum, emd_1d, exact_spectrum


def run(quick: bool = False):
    n = 600 if quick else 1500
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.35, (n, 5)).astype(np.float32)
    ker = gaussian(bandwidth=2.0)
    rows = []

    lam = top_eigenvalue_exact(ker, x)
    for method in ("power", "noisy_power"):
        for t in (100, 300):
            t0 = time.perf_counter()
            res = top_eigenvalue(x, ker, t=t, method=method, seed=0)
            us = (time.perf_counter() - t0) * 1e6
            rel = abs(res.eigenvalue - lam) / lam
            rows.append(emit(f"eigen/{method}/t={t}", us,
                             f"rel_err={rel:.4f};kernel_evals={res.kernel_evals}"))

    truth = exact_spectrum(ker, x)
    budgets = [(12, 24)] if quick else [(12, 24), (32, 64)]
    for srcs, walks in budgets:
        t0 = time.perf_counter()
        sp = approximate_spectrum(x, ker, length=8, num_sources=srcs,
                                  walks_per_source=walks, seed=0)
        us = (time.perf_counter() - t0) * 1e6
        rows.append(emit(f"spectrum/{srcs}x{walks}", us,
                         f"emd={emd_1d(sp.eigenvalues, truth):.4f};"
                         f"kernel_evals={sp.kernel_evals}"))
    return rows
