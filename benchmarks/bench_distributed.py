"""Sharded engine benchmark: fused collective draws vs the frozen
host-orchestrated psum loop (DESIGN.md §9).

Baseline = the pre-PR-4 distributed pattern this PR deleted: level-1 block
sums come back to the host as one psum'd/concatenated array per step, the
host makes every sampling decision with numpy (block draw against the
totals, gather of the chosen block's rows, level-2 kernel evals + draw),
and the next step dispatches again -- one full device->host round-trip per
walk step per stage.  Do not "fix" this copy; it is the reference the
sharded engine is measured against.

New path = ``ShardedBlocks.walk_scan``: T steps, one program, one psum per
step, one transfer out.

Measured at n = 16384 (quick: n = 4096) on however many devices the
process sees -- run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
for the CI 8-shard configuration.  Writes ``BENCH_distributed.json``.

derived = "steps_per_sec=<new>;host_steps_per_sec=<old>;speedup=<x>"
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.compat import shard_map
from repro.core.kernels_fn import gaussian
from repro.kernels.kde_sampler.sharded import ShardedBlocks
from repro.obs.export import telemetry_block

_JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_distributed.json"


# --------------------------------------------------------------------- #
# Frozen host-orchestrated baseline (the deleted code path)
# --------------------------------------------------------------------- #
def _frozen_block_sums(mesh, kernel, num_blocks_per_shard, data_axes=("data",)):
    """Frozen copy of the pre-PR-4 ``sharded_block_sums``: local per-block
    sums concatenated over shards, consumed by the host."""
    from jax.sharding import PartitionSpec as P
    axes = tuple(data_axes)

    def local(y, x_shard):
        ns = x_shard.shape[0]
        bs = ns // num_blocks_per_shard
        kv = kernel.pairwise(y, x_shard)
        return kv.reshape(y.shape[0], num_blocks_per_shard, bs).sum(-1)

    return jax.jit(shard_map(local, mesh=mesh, in_specs=(P(), P(axes)),
                             out_specs=P(None, axes)))


def _host_orchestrated_walk(mesh, x, xs, kernel, starts, length, bs, rng):
    """Frozen host loop: per step, one distributed level-1 read, then every
    sampling decision on the host against the psum'd/gathered totals."""
    n = x.shape[0]
    nbps = (n // len(jax.devices())) // bs
    f_bs = _frozen_block_sums(mesh, kernel, nbps)
    cur = starts.copy()
    xd = jnp.asarray(x)
    for _ in range(length):
        sums = np.array(f_bs(xd[jnp.asarray(cur)], xs))      # (w, B) to host
        own = cur // bs
        sums[np.arange(len(cur)), own] = np.maximum(
            sums[np.arange(len(cur)), own] - 1.0, 1e-12)
        c = np.cumsum(sums, axis=1)
        u = rng.uniform(size=(len(cur), 1)) * c[:, -1:]
        blk = (u > c).sum(axis=1).clip(0, sums.shape[1] - 1)
        nxt = np.zeros(len(cur), np.int64)
        for i, b in enumerate(blk):                          # host level-2
            lo, hi = b * bs, min((b + 1) * bs, n)
            kv = np.array(kernel.pairwise(xd[cur[i]][None], xd[lo:hi]))[0]
            kv[lo + np.arange(hi - lo) == cur[i]] = 0.0
            cc = np.cumsum(kv)
            nxt[i] = lo + int((rng.uniform() * cc[-1] > cc).sum())
        cur = nxt
    return cur


def _time(fn, repeats=3, warmup=1):
    """Best-of-N FENCED wall seconds via ``obs.Timer`` (the return value
    of ``fn`` is ``block_until_ready``'d before the clock stops); min is
    robust against background load on shared CPUs."""
    from repro.obs.metrics import Timer
    return Timer("bench").timeit(fn, repeats=repeats, warmup=warmup,
                                 reduce="min") / 1e6


def _scaling(quick: bool, mesh, devices: int) -> dict:
    """n-sweep of the fused sharded walk up to ~10^6 points (DESIGN.md §14).

    Uses the subsampled level-1 configuration (``exact=False``,
    s = 16 rows per block) so the per-step cost stays O(w * B * s / p)
    per shard and the sweep reaches 10^6 points in quick mode.  Each entry
    carries a measured-roofline fraction: per-device operand bytes (local
    level-1 subsample read + owner-shard level-2 slab) and the one-psum
    collective payload against ``chip_spec_for_backend()``.
    """
    from repro.roofline.analysis import (chip_spec_for_backend,
                                         measured_roofline)
    sizes = [4096, 65536, 1048576] if quick else [
        4096, 65536, 262144, 1048576]
    w, length, d, s = 256, 4, 8, 16
    spec = chip_spec_for_backend()
    rng = np.random.default_rng(0)
    entries = []
    for n in sizes:
        x = rng.normal(0, 0.5, (n, d)).astype(np.float32)
        ker = gaussian(2.0)
        bs = max(int(np.sqrt(n)), 16)
        eng = ShardedBlocks(mesh, x, ker, block_size=bs,
                            samples_per_block=s, exact=False)
        starts = jnp.asarray(rng.integers(0, n, w), jnp.int32)
        keys = jax.random.split(jax.random.PRNGKey(3), length)

        def stepper():
            end, *_ = eng.walk_scan(starts, keys)
            np.asarray(end)

        t = _time(stepper, repeats=3, warmup=1)
        sps = w * length / t
        num_blocks = -(-n // bs)
        # Per-device operand traffic per step: this shard's slice of the
        # subsampled level-1 read plus the (owner-shard) level-2 slab,
        # amortized 1/p; the psum moves the (w, p) candidate table.
        bytes_dev = (w * (num_blocks * s // devices) * d * 4
                     + w * bs * d * 4 // devices)
        coll_dev = 3 * w * devices * 4
        flops_dev = 2.0 * w * (num_blocks * s // devices + bs // devices) * d
        mr = measured_roofline(t / length, flops_dev, bytes_dev, spec=spec,
                               chips=devices,
                               coll_bytes_per_device=coll_dev)
        emit(f"distributed_walk_scaling/n={n}_p{devices}",
             t * 1e6 / (w * length),
             f"steps_per_sec={sps:.0f};"
             f"roofline_frac={mr.achieved_fraction:.3f};"
             f"dominant={mr.dominant}")
        entries.append(dict(
            n=n, block_size=bs, walkers=w, length=length, d=d,
            samples_per_block=s, steps_per_sec=sps,
            us_per_step=t / length * 1e6,
            modeled_bytes_per_device_step=bytes_dev,
            psum_bytes_per_device_step=coll_dev,
            roofline=dict(fraction=mr.achieved_fraction,
                          dominant=mr.dominant,
                          achieved_bw=mr.achieved_bw)))
    return dict(devices=devices, spec=spec.as_dict(), entries=entries)


def run(quick: bool = False) -> None:
    """Benchmark entry point (called by ``benchmarks.run``)."""
    n = 4096 if quick else 16384
    w, length = 256, 8
    d = 8
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.5, (n, d)).astype(np.float32)
    ker = gaussian(2.0)
    devices = len(jax.devices())
    mesh = jax.make_mesh((devices,), ("data",))
    bs = max(int(np.sqrt(n)), 16)

    eng = ShardedBlocks(mesh, x, ker, block_size=bs, exact=True)
    starts = rng.integers(0, n, w)
    keys = jax.random.split(jax.random.PRNGKey(1), length)

    def fused():
        end, *_ = eng.walk_scan(jnp.asarray(starts, jnp.int32), keys)
        np.asarray(end)

    t_fused = _time(fused)

    from repro.core.kde.distributed import make_sharded_dataset
    xs = make_sharded_dataset(mesh, x)
    host_repeats = 1 if not quick else 2

    def host():
        _host_orchestrated_walk(mesh, x, xs, ker, starts.copy(), length, bs,
                                np.random.default_rng(2))

    t_host = _time(host, repeats=host_repeats, warmup=1)

    steps = w * length
    new_sps = steps / t_fused
    old_sps = steps / t_host
    speedup = new_sps / old_sps
    emit(f"distributed_walk_n{n}_p{devices}", t_fused * 1e6 / steps,
         f"steps_per_sec={new_sps:.0f};host_steps_per_sec={old_sps:.0f};"
         f"speedup={speedup:.1f}")

    payload = {
        "n": n, "devices": devices, "walkers": w, "length": length,
        "block_size": bs,
        "fused_steps_per_sec": new_sps,
        "host_orchestrated_steps_per_sec": old_sps,
        "speedup": speedup,
        "scaling": _scaling(quick, mesh, devices),
        "telemetry": telemetry_block(wall_us=1e6 / new_sps),
    }
    _JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {_JSON_PATH.name}: {speedup:.1f}x over the "
          f"host-orchestrated psum loop on {devices} device(s)")


if __name__ == "__main__":
    run(quick=True)
