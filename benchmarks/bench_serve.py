"""Multi-tenant serving benchmark: the batched servable vs the
sequential per-request driver (DESIGN.md §13).

Both sides answer the IDENTICAL request stream -- mixed ``sample`` /
``query`` / ``walk`` / ``prob_of`` requests round-robined over S tenants
(distinct datasets, one shared static config so every tenant stacks into
the same batch groups -- ONE program per op per tick regardless of S or
R).  Hashed-level-1 tenants have data-dependent bucket layouts that can
never stack across datasets, so they serve in singleton groups and keep
roughly the sequential driver's throughput; the headline measures the
cross-tenant stacking win on blocked tenants, and a secondary
``serve_hash_mix`` line records the mixed blocked+hash case:

* **served** = ``KernelGraphServable``: each tick drains all concurrent
  requests into padded batch groups (one vmapped device program per
  (op, signature, bucket) group, per-request PRNG keys / status words);
* **sequential** = the pre-PR-8 driver: one ``NeighborSampler`` /
  estimator call per request, one program dispatch each.

Timing contract (ISSUE 8 satellite): the first tick / first pass runs
every program shape off-clock, and ``jax.block_until_ready`` fences the
timed region on both sides, so the artifact records steady-state device
time, not compiles or async-dispatch tails.  Writes ``BENCH_serve.json``
(p50/p99 submit->completion latency + throughput); the acceptance floor
is >= 3x served throughput at >= 16 concurrent mixed-tenant requests.

Measured at n = 1024 -- the dispatch-bound regime continuous batching
targets: many small concurrent requests against already-preprocessed
estimators, where per-request device work is tiny and the sequential
driver's cost is dominated by one program dispatch + sync per request.
As n grows, per-request compute dominates and both paths converge (at
n = 4096 the same mix measures ~2.3x); the win to report is the
request-rate regime, not the compute-bound one.

derived = "p50_ms=<x>;p99_ms=<x>;rps=<served>;seq_rps=<baseline>;speedup=<x>"
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.kernels_fn import gaussian
from repro.core.serving import KernelGraphServable
from repro.obs.export import telemetry_block

_JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_serve.json"


def _request_plan(rng, n, d, S, R, ticks):
    """Pre-generate the identical mixed request stream for both paths:
    one entry (tenant, op, payload, seed) per request.  The (op, tenant)
    mix is the same every tick -- steady-state serving, where every batch
    group's program shape was compiled by the warmup tick -- with payload
    contents re-drawn per request."""
    plan = []
    for t in range(ticks):
        tick = []
        for r in range(R):
            tenant = (r // 4) % S
            op = ("sample", "query", "walk", "prob_of")[r % 4]
            seed = 10_000 * t + r
            if op == "sample":
                payload = dict(src=rng.integers(0, n, size=16))
            elif op == "query":
                payload = dict(y=rng.normal(0, 0.6, size=(8, d))
                               .astype(np.float32))
            elif op == "walk":
                payload = dict(starts=rng.integers(0, n, size=8), length=4)
            else:
                payload = dict(src=rng.integers(0, n, size=16),
                               dst=rng.integers(0, n, size=16))
            tick.append((tenant, op, payload, seed))
        plan.append(tick)
    return plan


def _measure(datasets, ker, plan, warmup, level1s, S, R, ticks):
    """Run the served path and the sequential baseline over the SAME
    request plan; returns (p50_ms, p99_ms, served_rps, seq_rps,
    realized_evals) -- the last read off the servable's device counter
    words (DESIGN.md §15.1)."""
    srv = KernelGraphServable(max_resident=S)
    for i, x in enumerate(datasets):
        srv.add_tenant(f"t{i}", x, ker, block_size=32,
                       level1=level1s[i], seed=i)

    def submit_tick(tick):
        return [srv.submit(f"t{tenant}", op, seed=seed, **payload)
                for tenant, op, payload, seed in tick]

    submit_tick(warmup)
    srv.tick()                        # compiles every group shape off-clock
    lat = []
    t0 = time.perf_counter()
    for tick in plan:
        reqs = submit_tick(tick)
        st = srv.tick()
        assert st["failed"] == 0, st
        lat.extend(r.latency for r in reqs)
    t_served = time.perf_counter() - t0
    served_rps = (ticks * R) / t_served
    lat_ms = 1e3 * np.asarray(lat)

    # ---- sequential baseline: one engine call per request
    samplers = [srv.tenant(f"t{i}").admit() for i in range(S)]

    def run_one(tenant, op, payload):
        nbr = samplers[tenant]
        if op == "sample":
            return nbr.sample(payload["src"])
        if op == "walk":
            return nbr.walk(payload["starts"], payload["length"])
        if op == "prob_of":
            return nbr.prob_of(payload["src"], payload["dst"])
        if nbr.level1 == "hash":
            return np.asarray(nbr.hash_estimator.query(payload["y"]))
        return np.asarray(nbr.blocks.query(payload["y"]))

    for tenant, op, payload, _ in warmup:      # compile per-request shapes
        run_one(tenant, op, payload)
    jax.block_until_ready(tuple(s.x for s in samplers))
    t0 = time.perf_counter()
    for tick in plan:
        for tenant, op, payload, _ in tick:
            run_one(tenant, op, payload)
    jax.block_until_ready(tuple(s.x for s in samplers))
    t_seq = time.perf_counter() - t0
    seq_rps = (ticks * R) / t_seq

    p50 = float(np.percentile(lat_ms, 50))
    p99 = float(np.percentile(lat_ms, 99))
    return (p50, p99, served_rps, seq_rps,
            srv.report()["device_counters"]["evals"])


def run(quick: bool = False) -> None:
    """Benchmark entry point (called by ``benchmarks.run``)."""
    n = 1024                    # dispatch-bound serving regime (docstring)
    d, S, R = 8, 4, 32          # R >= 16 concurrent mixed-tenant requests
    ticks = 4 if quick else 16
    rng = np.random.default_rng(0)
    ker = gaussian(1.0)
    datasets = [rng.normal(0, 0.6, (n, d)).astype(np.float32) + 0.1 * i
                for i in range(S)]
    plan = _request_plan(rng, n, d, S, R, ticks + 1)
    warmup, plan = plan[0], plan[1:]

    # headline: every tenant shares the blocked static config, so the
    # whole tick collapses to one program per (op, bucket)
    p50, p99, served_rps, seq_rps, evals = _measure(
        datasets, ker, plan, warmup, ["blocked"] * S, S, R, ticks)
    speedup = served_rps / seq_rps
    emit(f"serve_multi_tenant_S{S}_R{R}_n{n}", R * ticks * 1e6 / served_rps,
         f"p50_ms={p50:.2f};p99_ms={p99:.2f};rps={served_rps:.0f};"
         f"seq_rps={seq_rps:.0f};speedup={speedup:.1f}")

    # secondary: half the tenants use hashed level-1 -- their layouts are
    # data-dependent, so they serve in singleton groups (no stacking win)
    hp50, hp99, h_rps, h_seq, h_evals = _measure(
        datasets, ker, plan, warmup,
        ["hash" if i % 2 else "blocked" for i in range(S)], S, R, ticks)
    emit(f"serve_hash_mix_S{S}_R{R}_n{n}", R * ticks * 1e6 / h_rps,
         f"p50_ms={hp50:.2f};p99_ms={hp99:.2f};rps={h_rps:.0f};"
         f"seq_rps={h_seq:.0f};speedup={h_rps / h_seq:.1f}")

    payload = {
        "n": n, "d": d, "tenants": S, "requests_per_tick": R,
        "ticks": ticks, "mix": ["sample", "query", "walk", "prob_of"],
        "level1": "blocked",
        "p50_latency_ms": p50, "p99_latency_ms": p99,
        "served_requests_per_sec": served_rps,
        "sequential_requests_per_sec": seq_rps,
        "throughput_speedup": speedup,
        "hash_mix": {
            "level1": ["hash" if i % 2 else "blocked" for i in range(S)],
            "p50_latency_ms": hp50, "p99_latency_ms": hp99,
            "served_requests_per_sec": h_rps,
            "sequential_requests_per_sec": h_seq,
            "throughput_speedup": h_rps / h_seq,
            "realized_evals": h_evals,
        },
        "telemetry": telemetry_block(wall_us=1e6 / served_rps,
                                     realized_evals=evals),
    }
    _JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {_JSON_PATH.name}: {speedup:.1f}x throughput over the "
          f"sequential driver at {R} concurrent mixed-tenant requests "
          f"(p50 {p50:.1f} ms, p99 {p99:.1f} ms)")


if __name__ == "__main__":
    run(quick=True)
