"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each bench module's
docstring for what the derived column encodes, and EXPERIMENTS.md
§Paper-claims for how these map onto the paper's Section 7 numbers).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only kde,lra,...]
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = {
    "kde": "benchmarks.bench_kde",                 # Table 1
    "sampling": "benchmarks.bench_sampling",       # fused engine vs seed
    "primitives": "benchmarks.bench_primitives",   # Table 2
    "lra": "benchmarks.bench_lra",                 # Figure 3
    "sparsify": "benchmarks.bench_sparsify",       # Figure 4 / §7.1
    "graph": "benchmarks.bench_graph",             # Thms 6.15 / 6.17
    "distributed": "benchmarks.bench_distributed", # sharded engine (§9)
    "eigen_spectrum": "benchmarks.bench_eigen_spectrum",  # Thms 5.22 / 5.17
    "attention": "benchmarks.bench_attention",     # framework integration
    "streaming": "benchmarks.bench_streaming",     # dynamic datasets (§12)
    "serve": "benchmarks.bench_serve",             # serving layer (§13)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", type=str, default="")
    ap.add_argument("--skip", type=str, default="",
                    help="comma-separated modules to exclude")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(BENCHES)
    only -= set(args.skip.split(",")) if args.skip else set()

    print("name,us_per_call,derived")
    failures = []
    for key, modname in BENCHES.items():
        if key not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run(quick=args.quick)
            print(f"# {key}: done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:  # keep going; report at the end
            failures.append((key, repr(e)))
            print(f"# {key}: FAILED {e!r}", flush=True)
    if failures:
        print(f"# {len(failures)} benchmark module(s) failed: {failures}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
