"""Figure 3 reproduction: low-rank approximation error vs rank on
MNIST-like / GloVe-like clouds; KDE sampling (Cor 5.14) vs the
Clarkson-Woodruff input-sparsity sketch (IS) vs iterative SVD.

derived = "rel_err=<KDE>/<IS>/<SVD>;eval_reduction=<x>;space_reduction=<x>"

The paper's headline: comparable Frobenius error with ~9x fewer kernel
evaluations and ~8x less space (Section 7.1).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.kernels_fn import laplacian, median_bandwidth
from repro.core.lowrank import (countsketch_lowrank, fkv_lowrank,
                                optimal_error, projection_error,
                                subspace_iteration)
from repro.data.synthetic_points import glove_like, mnist_like


def run(quick: bool = False):
    n = 1200 if quick else 2500
    ranks = [5, 10] if quick else [5, 10, 20, 40]
    rows = []
    for dsname, maker in (("mnist", mnist_like), ("glove", glove_like)):
        x = maker(n=n)
        ker = laplacian(bandwidth=median_bandwidth(jnp.asarray(x), ord=1))
        k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
        fro2 = np.linalg.norm(k, "fro") ** 2
        for r in ranks:
            t0 = time.perf_counter()
            res = fkv_lowrank(x, ker, rank=r, num_rows=25 * r,
                              estimator="rs", seed=0)
            t_kde = time.perf_counter() - t0
            err_kde = projection_error(k, res.u) / fro2

            t0 = time.perf_counter()
            u_is = countsketch_lowrank(k, r, max(4 * r, 32), seed=0)
            t_is = time.perf_counter() - t0
            err_is = projection_error(k, u_is) / fro2

            t0 = time.perf_counter()
            _, u_svd = subspace_iteration(k, r, iters=10, seed=0)
            t_svd = time.perf_counter() - t0
            err_svd = projection_error(k, u_svd) / fro2

            evals_baseline = n * n          # IS/SVD materialize K
            reduction = evals_baseline / max(res.kernel_evals, 1)
            space_reduction = n * n / (25 * r * n)
            rows.append(emit(
                f"lra/{dsname}/rank{r}", t_kde * 1e6,
                f"rel_err={err_kde:.4f}/{err_is:.4f}/{err_svd:.4f};"
                f"eval_reduction={reduction:.1f}x;"
                f"space_reduction={space_reduction:.1f}x"))
    return rows
