"""Graph applications: engine benchmark + Theorems 6.15 / 6.17 accuracy.

Part 1 (engine): the fused triangle inner loop (``triangle_edge_scan`` --
degree-ordered orientation, one shared level-1 read, all neighbor draws and
the reweighting under ``lax.scan``, DESIGN.md §7) and the fused arboricity
edge sampler (``edge_batch_scan``) against FROZEN copies of the PR-2 host
loops: per-draw ``nbr.sample`` + an (m, m) pairwise matrix materialized for
its diagonal (triangles), and the five-round-trip-per-batch edge loop
(arboricity).  Writes ``BENCH_graph.json`` with inner-loop throughput and
speedups; the PR-3 acceptance floor is >= 3x at n = 16384 on CPU.

derived = "draws_per_sec=<new>;host_draws_per_sec=<old>;speedup=<x>"

Part 2 (accuracy): estimator accuracy vs the exact dense oracles.

derived = "rel_err=<e>;kernel_evals=<n>"
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.graph.arboricity import estimate_arboricity, exact_arboricity
from repro.core.graph.triangles import (estimate_triangle_weight,
                                        exact_triangle_weight)
from repro.core.kernels_fn import Kernel, gaussian
from repro.core.sampling.edge import NeighborSampler
from repro.core.sampling.vertex import DegreeSampler, approximate_degrees
from repro.data.synthetic_points import gaussian_clusters
from repro.obs.export import telemetry_block

_JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_graph.json"


# --------------------------------------------------------------------- #
# Frozen PR-2 host loops -- the baselines every engine change is measured
# against.  Do not "fix" these copies; they are the reference.
# --------------------------------------------------------------------- #
def _precedes_host(deg: np.ndarray, a: np.ndarray, b: np.ndarray):
    return (deg[a] < deg[b]) | ((deg[a] == deg[b]) & (a < b))


def _host_triangle_inner(kernel: Kernel, nbr: NeighborSampler,
                         deg: np.ndarray, u: np.ndarray, v: np.ndarray,
                         neighbor_samples: int) -> np.ndarray:
    """Frozen seed inner loop: one ``nbr.sample`` round-trip per draw and
    an (m, m) pairwise matrix materialized per draw for its diagonal."""
    xj = nbr.x
    kuv = np.diagonal(np.asarray(
        kernel.pairwise(xj[jnp.asarray(u)], xj[jnp.asarray(v)])))
    w_hat = np.zeros(len(u))
    for _ in range(neighbor_samples):
        w, _ = nbr.sample(v)
        valid = _precedes_host(deg, v, w) & (w != u)
        kuw = np.diagonal(np.asarray(
            kernel.pairwise(xj[jnp.asarray(u)], xj[jnp.asarray(w)])))
        w_hat += valid * kuv * kuw
    return w_hat * deg[v] / neighbor_samples


def _host_arboricity_edges(deg: DegreeSampler, nbr: NeighborSampler,
                           kernel: Kernel, m: int, batch: int = 512):
    """Frozen seed edge loop: five device round-trips per batch."""
    xj = nbr.x
    srcs, dsts, ws = [], [], []
    for lo in range(0, m, batch):
        b = min(batch, m - lo)
        u = deg.sample(b)
        v, q_uv = nbr.sample(u)
        q_vu = nbr.prob_of(v, u)
        p_e = deg.prob(u) * q_uv + deg.prob(v) * q_vu
        kuv = np.diagonal(np.asarray(kernel.pairwise(
            xj[jnp.asarray(u)], xj[jnp.asarray(v)])))
        srcs.append(u)
        dsts.append(v)
        ws.append(kuv / (m * np.maximum(p_e, 1e-30)))
    return np.concatenate(srcs), np.concatenate(dsts), np.concatenate(ws)


def _time(fn, repeats=3, warmup=1):
    """Best-of-N FENCED wall seconds via ``obs.Timer`` (the return value
    of ``fn`` is ``block_until_ready``'d before the clock stops); min is
    robust against background load on shared CPUs."""
    from repro.obs.metrics import Timer
    return Timer("bench").timeit(fn, repeats=repeats, warmup=warmup,
                                 reduce="min") / 1e6


def _engine(quick: bool):
    rows, results = [], []
    n = 4096 if quick else 16384
    m, ns, d, spb = 2048, 16, 16, 16
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.5, (n, d)).astype(np.float32)
    ker = gaussian(bandwidth=4.0)

    # ---------------- triangles: fused scan vs frozen per-draw loop
    nbr_f = NeighborSampler(x, ker, mode="blocked", samples_per_block=spb,
                            seed=2)
    deg_f = approximate_degrees(nbr_f.blocks)
    degs_dev = jnp.asarray(deg_f, jnp.float32)
    u = rng.integers(0, n, size=m)
    v = rng.integers(0, n - 1, size=m)
    v = np.where(v >= u, v + 1, v)
    t_fused = _time(lambda: nbr_f.triangle_batches(u, v, degs_dev, ns),
                    repeats=5, warmup=1)

    nbr_h = NeighborSampler(x, ker, mode="blocked", samples_per_block=spb,
                            seed=2)
    deg_h = approximate_degrees(nbr_h.blocks)
    swap = ~_precedes_host(deg_h, u, v)          # seed oriented on host
    uo = np.where(swap, v, u)
    vo = np.where(swap, u, v)
    t_host = _time(lambda: _host_triangle_inner(ker, nbr_h, deg_h, uo, vo,
                                                ns),
                   repeats=3, warmup=1)

    draws = m * ns
    tri_speedup = t_host / t_fused
    rows.append(emit(
        f"triangles/inner_loop/n={n}", t_fused * 1e6,
        f"draws_per_sec={draws / t_fused:.0f};"
        f"host_draws_per_sec={draws / t_host:.0f};"
        f"speedup={tri_speedup:.1f}x"))
    results.append(dict(
        pipeline="triangles", n=n, num_edges=m, neighbor_samples=ns,
        inner_loop_sec=dict(fused=t_fused, host_loop=t_host),
        draws_per_sec=dict(fused=draws / t_fused, host_loop=draws / t_host),
        speedup=tri_speedup))

    # ---------------- arboricity: fused edge scan vs frozen batch loop
    t_edges = 4096
    deg_s = DegreeSampler(nbr_f.blocks, seed=1)
    cdf, degs = deg_s.cdf_device, deg_s.degrees_device
    t_arb_fused = _time(lambda: nbr_f.edge_batches(cdf, degs, deg_s.total,
                                                   t_edges, batch=1024),
                        repeats=5, warmup=1)
    deg_s2 = DegreeSampler(nbr_h.blocks, seed=1)
    t_arb_host = _time(lambda: _host_arboricity_edges(deg_s2, nbr_h, ker,
                                                      t_edges, batch=512),
                       repeats=3, warmup=1)
    arb_speedup = t_arb_host / t_arb_fused
    rows.append(emit(
        f"arboricity/inner_loop/n={n}", t_arb_fused * 1e6,
        f"edges_per_sec={t_edges / t_arb_fused:.0f};"
        f"host_edges_per_sec={t_edges / t_arb_host:.0f};"
        f"speedup={arb_speedup:.1f}x"))
    results.append(dict(
        pipeline="arboricity", n=n, num_edges=t_edges,
        inner_loop_sec=dict(fused=t_arb_fused, host_loop=t_arb_host),
        edges_per_sec=dict(fused=t_edges / t_arb_fused,
                           host_loop=t_edges / t_arb_host),
        speedup=arb_speedup))
    return rows, results


def _accuracy(quick: bool):
    rows, results = [], []
    n = 600 if quick else 1200
    x, _ = gaussian_clusters(n=n, d=4, k=2, spread=0.3, sep=1.2, seed=3)
    ker = gaussian(bandwidth=1.0)

    truth = exact_arboricity(ker, x)
    for budget in (2 * n, 8 * n):
        t0 = time.perf_counter()
        res = estimate_arboricity(x, ker, num_edges=budget,
                                  estimator="stratified", seed=0)
        us = (time.perf_counter() - t0) * 1e6
        rel = abs(res.density - truth) / truth
        rows.append(emit(f"arboricity/m={budget}", us,
                         f"rel_err={rel:.4f};kernel_evals={res.kernel_evals}"))
        results.append(dict(pipeline="arboricity_accuracy", n=n, m=budget,
                            rel_err=rel, kernel_evals=res.kernel_evals))

    truth_t = exact_triangle_weight(ker, x)
    for ne, nsamp in ((200, 8), (600, 24)):
        t0 = time.perf_counter()
        res = estimate_triangle_weight(x, ker, num_edges=ne,
                                       neighbor_samples=nsamp,
                                       estimator="stratified", seed=0)
        us = (time.perf_counter() - t0) * 1e6
        rel = abs(res.total_weight - truth_t) / truth_t
        rows.append(emit(f"triangles/R={ne}x{nsamp}", us,
                         f"rel_err={rel:.4f};kernel_evals={res.kernel_evals}"))
        results.append(dict(pipeline="triangles_accuracy", n=n, m=ne,
                            neighbor_samples=nsamp, rel_err=rel,
                            kernel_evals=res.kernel_evals))
    return rows, results


def run(quick: bool = False):
    rows, results = _engine(quick)
    rows2, results2 = _accuracy(quick)
    _JSON_PATH.write_text(json.dumps(dict(
        benchmark="bench_graph", backend=jax.default_backend(), quick=quick,
        telemetry=telemetry_block(),
        results=results + results2), indent=2) + "\n")
    return rows + rows2
