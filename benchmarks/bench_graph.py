"""Theorems 6.15 / 6.17: arboricity and weighted-triangle estimation
accuracy vs the exact oracles.

derived = "rel_err=<e>;kernel_evals=<n>"
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.graph.arboricity import estimate_arboricity, exact_arboricity
from repro.core.graph.triangles import (estimate_triangle_weight,
                                        exact_triangle_weight)
from repro.core.kernels_fn import gaussian
from repro.data.synthetic_points import gaussian_clusters


def run(quick: bool = False):
    n = 600 if quick else 1200
    x, _ = gaussian_clusters(n=n, d=4, k=2, spread=0.3, sep=1.2, seed=3)
    ker = gaussian(bandwidth=1.0)
    rows = []

    truth = exact_arboricity(ker, x)
    for budget in (2 * n, 8 * n):
        t0 = time.perf_counter()
        res = estimate_arboricity(x, ker, num_edges=budget,
                                  estimator="stratified", seed=0)
        us = (time.perf_counter() - t0) * 1e6
        rel = abs(res.density - truth) / truth
        rows.append(emit(f"arboricity/m={budget}", us,
                         f"rel_err={rel:.4f};kernel_evals={res.kernel_evals}"))

    truth_t = exact_triangle_weight(ker, x)
    for ne, ns in ((200, 8), (600, 24)):
        t0 = time.perf_counter()
        res = estimate_triangle_weight(x, ker, num_edges=ne,
                                       neighbor_samples=ns,
                                       estimator="stratified", seed=0)
        us = (time.perf_counter() - t0) * 1e6
        rel = abs(res.total_weight - truth_t) / truth_t
        rows.append(emit(f"triangles/R={ne}x{ns}", us,
                         f"rel_err={rel:.4f};kernel_evals={res.kernel_evals}"))
    return rows
