"""Table 1 analog: KDE query cost per estimator x kernel.

derived = "evals_per_query=<n>;rel_err=<e>" -- the paper's cost model is
kernel evaluations (query time ~ d / (eps^2 tau^p)); we report both wall
time and the hardware-independent eval count.

Sections (all written to ``BENCH_kde.json``):

* ``matrix``    -- every estimator backend (exact / rs / stratified /
  host ``GridHBE`` / device ``kde_hash``) on every Table-1 kernel;
* ``mesh``      -- the sharded backends (``ShardedKDE`` exact ring,
  ``HashedKDE(mesh=)`` one-psum hashed table) when >= 2 devices are
  visible (CI runs this under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``);
* ``pipelines`` -- the acceptance numbers for ``estimator="hash"``:
  degrees->sparsify and degrees->triangles eval counters vs the
  ``StratifiedKDE`` baseline at n=16384 (full mode), plus the sparsifier
  spectral-error comparison at a size where the dense Laplacian is
  materializable.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.kde.base import ExactKDE, make_estimator
from repro.core.kernels_fn import (exponential, gaussian, laplacian,
                                   rational_quadratic)
from repro.obs.export import telemetry_block

_JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_kde.json"


def _matrix(quick: bool, rows, results):
    n = 2000 if quick else 4000
    d = 16 if quick else 32
    m = 32
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.4, (n, d)).astype(np.float32)
    q = rng.normal(0, 0.4, (m, d)).astype(np.float32)
    kernels = [gaussian(2.0), exponential(2.0), laplacian(4.0),
               rational_quadratic(bandwidth=2.0)]
    out = []
    for ker in kernels:
        oracle = ExactKDE(x, ker)
        truth = np.asarray(oracle.query(q))
        for name in ("exact", "rs", "stratified", "grid_hbe", "hash"):
            if name == "grid_hbe" and ker.name != "laplacian":
                continue            # host loop: keep one representative
            est = make_estimator(name, x, ker, seed=0, tau=0.05, eps=0.3)
            est.evals = 0
            reps = 2 if name == "grid_hbe" else 3
            us = timeit(lambda: np.asarray(est.query(q)), repeats=reps)
            evals_per_q = est.evals / max(m * (reps + 1), 1)
            vals = np.asarray(est.query(q))
            rel = float(np.mean(np.abs(vals / truth - 1)))
            rows.append(emit(
                f"kde_query/{ker.name}/{name}", us / m,
                f"evals_per_query={evals_per_q:.0f};rel_err={rel:.4f}"))
            out.append(dict(kernel=ker.name, estimator=name,
                            us_per_query=us / m,
                            evals_per_query=evals_per_q, rel_err=rel))
    results["matrix"] = dict(n=n, d=d, m=m, entries=out)


def _mesh(quick: bool, rows, results):
    ndev = len(jax.devices())
    if ndev < 2:
        results["mesh"] = dict(skipped=True, devices=ndev)
        rows.append(emit("kde_query/mesh", 0.0,
                         f"skipped=1_device (run under XLA_FLAGS="
                         f"--xla_force_host_platform_device_count=8)"))
        return
    from repro.core.kde.distributed import ShardedKDE
    from repro.core.kde.hashed import HashedKDE
    n = 2048 if quick else 8192
    d = 16
    m = 64
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.4, (n, d)).astype(np.float32)
    q = rng.normal(0, 0.4, (m, d)).astype(np.float32)
    ker = gaussian(2.0)
    truth = np.asarray(ExactKDE(x, ker).query(q))
    mesh = jax.make_mesh((ndev,), ("data",))
    out = []
    for name, est in (("sharded_exact", ShardedKDE(mesh, x, ker,
                                                   exact=True)),
                      ("sharded_hash", HashedKDE(x, ker, mesh=mesh,
                                                 num_far_samples=128))):
        est.evals = 0
        us = timeit(lambda: np.asarray(est.query(q)), repeats=3)
        evals_per_q = est.evals / (m * 4)
        rel = float(np.mean(np.abs(np.asarray(est.query(q)) / truth - 1)))
        rows.append(emit(
            f"kde_query/mesh{ndev}/{name}", us / m,
            f"evals_per_query={evals_per_q:.0f};rel_err={rel:.4f}"))
        out.append(dict(estimator=name, us_per_query=us / m,
                        evals_per_query=evals_per_q, rel_err=rel))
    results["mesh"] = dict(n=n, d=d, m=m, devices=ndev, entries=out)


def _spectral_error(g, l_true, probes: int = 24, seed: int = 1) -> float:
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((l_true.shape[0], probes))
    v -= v.mean(0)
    ratios = np.einsum("ij,ij->j", v, g.laplacian_dense() @ v) / \
        np.einsum("ij,ij->j", v, l_true @ v)
    return float(np.abs(ratios - 1.0).max())


def _pipelines(quick: bool, rows, results):
    from repro.core.graph.triangles import estimate_triangle_weight
    from repro.core.sparsify import spectral_sparsify
    # -------- eval counters at scale (the acceptance numbers) -------- #
    n = 2048 if quick else 16384
    d = 16
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.5, (n, d)).astype(np.float32)
    ker = gaussian(bandwidth=4.0)
    t = 4 * n
    counters = {}
    for name in ("stratified", "hash"):
        t0 = time.perf_counter()
        g = spectral_sparsify(x, ker, num_edges=t, estimator=name, seed=0)
        sp_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        tri = estimate_triangle_weight(x, ker, 2048, 16, estimator=name,
                                       seed=0)
        tri_s = time.perf_counter() - t0
        counters[name] = dict(
            sparsify_evals=int(g.kernel_evals),
            sparsify_queries=int(g.kde_queries), sparsify_sec=sp_s,
            triangles_evals=int(tri.kernel_evals), triangles_sec=tri_s)
    sp_ratio = counters["hash"]["sparsify_evals"] \
        / counters["stratified"]["sparsify_evals"]
    tri_ratio = counters["hash"]["triangles_evals"] \
        / counters["stratified"]["triangles_evals"]
    rows.append(emit(
        f"kde_pipelines/evals/n={n}", 0.0,
        f"sparsify_hash_over_stratified={sp_ratio:.3f};"
        f"triangles_hash_over_stratified={tri_ratio:.3f}"))
    # -------- spectral error where L is materializable --------------- #
    n_sp = 1024 if quick else 2048
    x_sp = rng.normal(0, 0.35, (n_sp, 8)).astype(np.float32)
    ker_sp = gaussian(bandwidth=3.0)
    k_sp = np.asarray(ker_sp.matrix(jnp.asarray(x_sp)), np.float64)
    np.fill_diagonal(k_sp, 0.0)
    l_true = np.diag(k_sp.sum(1)) - k_sp
    errs = {}
    for name in ("stratified", "hash"):
        g = spectral_sparsify(x_sp, ker_sp, num_edges=16 * n_sp,
                              estimator=name, seed=0)
        errs[name] = _spectral_error(g, l_true)
    rows.append(emit(
        f"kde_pipelines/spectral_error/n={n_sp}", 0.0,
        f"stratified={errs['stratified']:.4f};hash={errs['hash']:.4f};"
        f"ratio={errs['hash'] / errs['stratified']:.2f}"))
    results["pipelines"] = dict(
        n=n, t=t, counters=counters,
        evals_ratio=dict(sparsify=sp_ratio, triangles=tri_ratio),
        spectral_error=dict(n=n_sp, t=16 * n_sp, **errs,
                            ratio=errs["hash"] / errs["stratified"]))


def _precision_scaling(quick: bool, rows, results):
    """f32 vs bf16 level-1 sweep throughput, n-sweep up to ~10^6.

    The bf16 policy (DESIGN.md §14) halves the dataset bytes the level-1
    sweep streams while keeping f32 accumulation, so the speedup target is
    >= 1.5x at n >= 262144 with rel-err within ``2 * BF16_REL_ERR``.  Each
    entry carries a measured-roofline fraction from the modeled sweep
    traffic (n * d operand bytes per query batch) against the backend
    peaks.
    """
    from repro.kernels.kde_sampler.ref import BF16_REL_ERR
    from repro.roofline import analysis as _roofline
    sizes = [65536, 262144, 1048576] if quick else [
        65536, 262144, 524288, 1048576]
    d, m = 16, 64
    spec = _roofline.chip_spec_for_backend()
    entries = []
    for n in sizes:
        rng = np.random.default_rng(0)
        x = rng.normal(0, 0.5, (n, d)).astype(np.float32)
        q = rng.normal(0, 0.5, (m, d)).astype(np.float32)
        ker = gaussian(bandwidth=4.0)
        per = {}
        for prec in ("f32", "bf16"):
            est = ExactKDE(x, ker, precision=prec)
            reps = 3 if n >= 1048576 else 5
            us = timeit(lambda: np.asarray(est.query(q)), repeats=reps)
            t = us * 1e-6
            in_bytes = _roofline.dtype_bytes(
                "bfloat16" if prec == "bf16" else "float32")
            # Sweep traffic: the dataset tile stream dominates (queries and
            # the f32 accumulator are tile-resident).
            bytes_moved = float(n) * d * in_bytes + m * d * 4 + m * 4
            flops = 2.0 * n * m * d
            mr = _roofline.measured_roofline(t, flops, bytes_moved,
                                             spec=spec)
            per[prec] = dict(us_per_batch=us,
                             evals_per_sec=n * m / t,
                             vals=np.asarray(est.query(q), np.float64),
                             roofline=dict(fraction=mr.achieved_fraction,
                                           dominant=mr.dominant,
                                           achieved_bw=mr.achieved_bw))
        rel = float(np.max(np.abs(per["bf16"]["vals"] / per["f32"]["vals"]
                                  - 1.0)))
        speedup = per["f32"]["us_per_batch"] / per["bf16"]["us_per_batch"]
        rows.append(emit(
            f"kde_precision/n={n}", per["bf16"]["us_per_batch"] / m,
            f"bf16_speedup={speedup:.2f}x;rel_err={rel:.2e};"
            f"bound={2 * BF16_REL_ERR:.2e};"
            f"roofline_frac={per['bf16']['roofline']['fraction']:.3f}"))
        entries.append(dict(
            n=n, d=d, m=m, bf16_speedup=speedup, bf16_rel_err=rel,
            rel_err_bound=2 * BF16_REL_ERR,
            f32={k: v for k, v in per["f32"].items() if k != "vals"},
            bf16={k: v for k, v in per["bf16"].items() if k != "vals"}))
    results["precision"] = dict(kernel="gaussian", spec=spec.as_dict(),
                                entries=entries)


def run(quick: bool = False):
    rows, results = [], {}
    _matrix(quick, rows, results)
    _mesh(quick, rows, results)
    _pipelines(quick, rows, results)
    _precision_scaling(quick, rows, results)
    _JSON_PATH.write_text(json.dumps(dict(
        benchmark="bench_kde", backend=jax.default_backend(), quick=quick,
        telemetry=telemetry_block(),
        results=results), indent=2) + "\n")
    return rows
