"""Table 1 analog: KDE query cost per estimator x kernel.

derived = "evals_per_query=<n>;rel_err=<e>" -- the paper's cost model is
kernel evaluations (query time ~ d / (eps^2 tau^p)); we report both wall
time and the hardware-independent eval count.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.kde.base import ExactKDE, make_estimator
from repro.core.kernels_fn import (exponential, gaussian, laplacian,
                                   rational_quadratic)


def run(quick: bool = False):
    n = 2000 if quick else 4000
    d = 16 if quick else 32
    m = 32
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.4, (n, d)).astype(np.float32)
    q = rng.normal(0, 0.4, (m, d)).astype(np.float32)
    kernels = [gaussian(2.0), exponential(2.0), laplacian(4.0),
               rational_quadratic(bandwidth=2.0)]
    rows = []
    for ker in kernels:
        oracle = ExactKDE(x, ker)
        truth = np.asarray(oracle.query(q))
        for name in ("exact", "rs", "stratified", "grid_hbe"):
            if name == "grid_hbe" and ker.name != "laplacian":
                continue
            est = make_estimator(name, x, ker, seed=0, tau=0.05, eps=0.3)
            est.evals = 0
            us = timeit(lambda: np.asarray(est.query(q)),
                        repeats=2 if name == "grid_hbe" else 3)
            evals_per_q = est.evals / max(m * 3, 1)
            vals = np.asarray(est.query(q))
            rel = float(np.mean(np.abs(vals / truth - 1)))
            rows.append(emit(
                f"kde_query/{ker.name}/{name}", us / m,
                f"evals_per_query={evals_per_q:.0f};rel_err={rel:.4f}"))
    return rows
