"""Fused sampling engine vs the seed host-loop sampler (DESIGN.md §3).

Measures random-walk stepping throughput (walk-steps/sec = walkers * steps /
wall-clock) and the sparsifier's inner loop (neighbor sample + prob_of
recompute per batch) for the device-resident engine against a frozen copy
of the seed's host-loop ``NeighborSampler``.

derived = "steps_per_sec=<new>;seed_steps_per_sec=<old>;speedup=<x>"

Also writes ``BENCH_sampling.json`` at the repo root so the perf trajectory
of the sampling engine is tracked from PR 1 onward.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.kernels_fn import Kernel, gaussian
from repro.core.sampling.edge import NeighborSampler
from repro.kernels.kde_sampler import ops as _sampler_ops
from repro.roofline import analysis as _roofline
from repro.obs.export import telemetry_block

_JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_sampling.json"


# --------------------------------------------------------------------- #
# Frozen seed implementation (host loop over blocks, numpy RNG) -- the
# baseline every future engine change is measured against.
# --------------------------------------------------------------------- #
class SeedHostSampler:
    def __init__(self, x, kernel: Kernel, samples_per_block: int = 16,
                 seed: int = 0):
        self.x = jnp.asarray(x, jnp.float32)
        self.kernel = kernel
        self.n = int(x.shape[0])
        self.block_size = max(int(np.sqrt(self.n)), 16)
        self.num_blocks = (self.n + self.block_size - 1) // self.block_size
        self.samples_per_block = min(samples_per_block, self.block_size)
        self._rng = np.random.default_rng(seed)

    def _block_sums(self, q):
        cols, sizes = [], []
        for b in range(self.num_blocks):           # the seed's host loop
            lo = b * self.block_size
            hi = min(lo + self.block_size, self.n)
            size = hi - lo
            s = min(self.samples_per_block, size)
            idx = lo + self._rng.choice(size, size=s, replace=False)
            cols.append(np.pad(idx, (0, self.samples_per_block - s),
                               constant_values=idx[0] if s else lo))
            sizes.append(size * (1.0 / max(s, 1)))
        idx = jnp.asarray(np.stack(cols))
        scale = np.asarray(sizes, np.float32)
        sub = self.x[idx.reshape(-1)]
        kv = np.asarray(self.kernel.pairwise(q, sub))
        kv = kv.reshape(q.shape[0], self.num_blocks, self.samples_per_block)
        return kv.sum(-1) * scale[None, :]

    def _masked_block_sums(self, src):
        bs = self._block_sums(self.x[jnp.asarray(src)])
        own = src // self.block_size
        bs[np.arange(len(src)), own] = np.maximum(
            bs[np.arange(len(src)), own] - 1.0, 1e-12)
        return np.maximum(bs, 1e-12)

    def _in_block_row(self, src, blk):
        w = len(src)
        lo = blk * self.block_size
        cols = lo[:, None] + np.arange(self.block_size)[None, :]
        valid = cols < self.n
        cols_c = np.minimum(cols, self.n - 1)
        xs = self.x[jnp.asarray(src)]
        xb = self.x[jnp.asarray(cols_c.reshape(-1))].reshape(
            w, self.block_size, -1)
        kv = np.asarray(jax.vmap(
            lambda a, b: self.kernel.pairwise(a[None, :], b)[0])(xs, xb))
        kv = kv * valid
        kv[cols_c == src[:, None]] = 0.0
        return kv, cols_c

    def _cat_rows(self, p):
        c = np.cumsum(p, axis=1)
        c = c / c[:, -1:]
        u = self._rng.uniform(size=(p.shape[0], 1))
        return (u > c).sum(axis=1).clip(0, p.shape[1] - 1)

    def sample(self, src) -> Tuple[np.ndarray, np.ndarray]:
        src = np.asarray(src)
        bs = self._masked_block_sums(src)
        pb = bs / bs.sum(axis=1, keepdims=True)
        blk = self._cat_rows(pb)
        kv, cols = self._in_block_row(src, blk)
        pin = kv / np.maximum(kv.sum(axis=1), 1e-30)[:, None]
        j = self._cat_rows(pin)
        nb = cols[np.arange(len(src)), j]
        return nb, pb[np.arange(len(src)), blk] * pin[np.arange(len(src)), j]

    def prob_of(self, src, dst):
        src, dst = np.asarray(src), np.asarray(dst)
        bs = self._masked_block_sums(src)
        pb = bs / bs.sum(axis=1, keepdims=True)
        blk = dst // self.block_size
        kv, _ = self._in_block_row(src, blk)
        rowsum = np.maximum(kv.sum(axis=1), 1e-30)
        kd = kv[np.arange(len(src)), dst - blk * self.block_size]
        return pb[np.arange(len(src)), blk] * kd / rowsum


def _walk_seed(sampler, starts, steps):
    cur = starts.copy()
    for _ in range(steps):
        cur, _ = sampler.sample(cur)
    return cur


def _time(fn, repeats=3, warmup=1):
    """Best-of-N FENCED wall seconds via ``obs.Timer`` (the return value
    of ``fn`` is ``block_until_ready``'d before the clock stops); min is
    robust against background load on shared CPUs."""
    from repro.obs.metrics import Timer
    return Timer("bench").timeit(fn, repeats=repeats, warmup=warmup,
                                 reduce="min") / 1e6


def _walk_scaling(quick: bool, rows: list):
    """n-sweep of walk throughput up to ~10^6 points (DESIGN.md §14).

    The fused walk's per-step cost under the walk-resident layout is
    O(cached cols) at level 1 plus O(walk_block_size) at level 2, both flat
    or sqrt-ish in n -- so walk-steps/sec should degrade only gently with n.
    ``cliff_ratio`` records thr(4096) / thr(n); the acceptance bound for
    this series is cliff_ratio <= 2 at n = 65536.

    Each entry also carries a measured-roofline fraction: modeled per-step
    operand bytes (cached level-1 read + level-2 stratum slab + CDF lanes)
    and kernel-eval flops against the backend's
    ``roofline.analysis.chip_spec_for_backend()`` peaks.
    """
    sizes = [4096, 65536, 1048576] if quick else [
        4096, 16384, 65536, 262144, 1048576]
    walkers, steps, d = 256, 4, 16
    fb = _roofline.dtype_bytes("float32")
    spec = _roofline.chip_spec_for_backend()
    entries = []
    base_sps = None
    for n in sizes:
        rng = np.random.default_rng(0)
        x = rng.normal(0, 0.5, (n, d)).astype(np.float32)
        ns = NeighborSampler(x, gaussian(bandwidth=4.0), mode="blocked",
                             samples_per_block=16, seed=0)
        starts = rng.integers(0, n, walkers).astype(np.int64)
        t = _time(lambda: ns.walk(starts, steps), repeats=3, warmup=1)
        sps = walkers * steps / t
        if base_sps is None:
            base_sps = sps
        cliff = base_sps / sps

        wbs, w_blocks, s_eff = _sampler_ops.walk_layout(
            ns.n, ns.block_size, ns.num_blocks, ns._cfg["s"])
        cols = w_blocks * s_eff
        evals_per_step = walkers * (cols + wbs)
        # Operand traffic per step: the cached level-1 read, the exact
        # level-2 stratum slab, and the grouped-CDF sum lanes.
        bytes_per_step = walkers * (cols * d + wbs * d
                                    + 4 * (w_blocks + wbs)) * fb
        flops_per_step = 2.0 * walkers * (cols + wbs) * d
        mr = _roofline.measured_roofline(t / steps, flops_per_step,
                                         bytes_per_step, spec=spec)
        rows.append(emit(
            f"sampling/walk_scaling/n={n}", t / steps * 1e6,
            f"steps_per_sec={sps:.0f};cliff_ratio={cliff:.2f};"
            f"evals_per_step={evals_per_step};"
            f"roofline_frac={mr.achieved_fraction:.3f}"))
        entries.append(dict(
            n=n, walkers=walkers, steps=steps, d=d,
            steps_per_sec=sps, us_per_step=t / steps * 1e6,
            cliff_ratio_vs_4096=cliff,
            walk_layout=dict(block_size=wbs, num_blocks=w_blocks,
                             samples_per_block=s_eff, cached_cols=cols),
            kernel_evals_per_step=evals_per_step,
            modeled_bytes_per_step=bytes_per_step,
            modeled_flops_per_step=flops_per_step,
            roofline=dict(fraction=mr.achieved_fraction,
                          dominant=mr.dominant,
                          achieved_bw=mr.achieved_bw)))
    return dict(walkers=walkers, steps=steps, d=d, spec=spec.as_dict(),
                entries=entries,
                cliff_ratio_65536=next(
                    (e["cliff_ratio_vs_4096"] for e in entries
                     if e["n"] == 65536), None))


def run(quick: bool = False):
    sizes = [4096] if quick else [4096, 16384, 65536]
    walkers = 256 if quick else 1024
    d = 16
    rows, results = [], []
    for n in sizes:
        rng = np.random.default_rng(0)
        x = rng.normal(0, 0.5, (n, d)).astype(np.float32)
        ker = gaussian(bandwidth=4.0)
        starts = rng.integers(0, n, walkers).astype(np.int64)

        new = NeighborSampler(x, ker, mode="blocked", samples_per_block=16,
                              seed=0)
        steps_new = 4 if quick else 8
        # record_path=True pins the PR-1 measurement semantics (the path
        # stack + transfer stays in the timed region) so the JSON series
        # remains comparable across PRs.
        t_new = _time(lambda: new.walk(starts, steps_new, record_path=True),
                      repeats=5, warmup=1)
        sps_new = walkers * steps_new / t_new

        old = SeedHostSampler(x, ker, samples_per_block=16, seed=0)
        steps_old = 2
        t_old = _time(lambda: _walk_seed(old, starts, steps_old), repeats=3,
                      warmup=1)
        sps_old = walkers * steps_old / t_old

        # sparsifier inner loop: neighbor sample + reverse prob recompute
        u = rng.integers(0, n, 512)
        v, _ = new.sample(u)
        t_sp_new = _time(lambda: (new.sample(u), new.prob_of(v, u)),
                         repeats=5, warmup=1)
        t_sp_old = _time(lambda: (old.sample(u), old.prob_of(v, u)),
                         repeats=2, warmup=0)

        speedup = sps_new / sps_old
        rows.append(emit(
            f"sampling/walk/n={n}", t_new / steps_new * 1e6 / 1.0,
            f"steps_per_sec={sps_new:.0f};seed_steps_per_sec={sps_old:.0f};"
            f"speedup={speedup:.1f}x"))
        rows.append(emit(
            f"sampling/sparsify_inner/n={n}", t_sp_new * 1e6,
            f"seed_us={t_sp_old * 1e6:.0f};speedup={t_sp_old / t_sp_new:.1f}x"))
        results.append(dict(
            n=n, walkers=walkers, d=d,
            walk_steps_per_sec=dict(fused=sps_new, seed_host_loop=sps_old),
            walk_speedup=speedup,
            sparsify_inner_sec=dict(fused=t_sp_new, seed_host_loop=t_sp_old),
            sparsify_inner_speedup=t_sp_old / t_sp_new))
    scaling = _walk_scaling(quick, rows)
    _JSON_PATH.write_text(json.dumps(dict(
        benchmark="bench_sampling", backend=jax.default_backend(),
        quick=quick, telemetry=telemetry_block(),
        results=results, scaling=scaling), indent=2) + "\n")
    return rows


if __name__ == "__main__":
    run(quick=True)
