"""Streaming engine benchmark: patch-on-read updates vs full rebuilds
(DESIGN.md §12).

Both paths apply the SAME mutation sequence -- batches of ~1% of the rows
(a third each insert / delete / update) -- and after every batch bring the
kernel-graph state current and answer one degree draw + one neighbor draw
at the new epoch:

* **streaming** = ``DynamicDataset`` + dataset-attached ``NeighborSampler``
  / ``DegreeSampler``: O(m) journal appends, then ONE coalesced patch
  folded into the first query (``patch_block_sums`` O(w·m) +
  ``degree_delta`` O(n·m) + prefix-CDF re-accumulation);
* **rebuild** = the frozen engines' only option before PR 7: reconstruct
  the level-1 block structure and recompute all n degrees (O(n²) exact
  evals) over the compacted live rows after every batch.

Measured at n = 16384 (quick: n = 4096), exact level-1 on both sides so
the work compared is identical math.  Writes ``BENCH_streaming.json``;
the PR-7 acceptance floor is ≥5x update throughput at n = 16384.

derived = "rows_per_sec=<new>;rebuild_rows_per_sec=<old>;speedup=<x>"
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.dataset import DynamicDataset
from repro.core.kernels_fn import gaussian
from repro.core.sampling.edge import NeighborSampler
from repro.core.sampling.vertex import DegreeSampler
from repro.obs.export import telemetry_block

_JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_streaming.json"


def _mutation_plan(rng, n, d, m, batches):
    """Pre-generate the identical mutation sequence for both paths.
    Deletes stay clear of the frontier rows [0, 64) and of each other."""
    mi = md = m // 3
    mu = m - mi - md
    dead_pool = rng.permutation(np.arange(64, n))[: md * batches]
    plan = []
    for b in range(batches):
        plan.append(dict(
            ins=rng.normal(0, 0.5, (mi, d)).astype(np.float32),
            dele=np.sort(dead_pool[b * md:(b + 1) * md]),
            upd_rows=rng.normal(0, 0.5, (mu, d)).astype(np.float32)))
    return plan


def _apply(ds, batch, rng):
    ds.insert_rows(batch["ins"])
    ds.delete_rows(batch["dele"])
    live = ds.live_slots()
    upd = rng.choice(live[live >= 64], size=len(batch["upd_rows"]),
                     replace=False)
    ds.update_rows(upd, batch["upd_rows"])


def run(quick: bool = False) -> None:
    """Benchmark entry point (called by ``benchmarks.run``)."""
    n = 4096 if quick else 16384
    d = 8
    m = max(n // 100, 3)          # ≤1% of rows mutated per batch
    batches = 3 if quick else 4
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.5, (n, d)).astype(np.float32)
    ker = gaussian(2.0)
    bs = max(int(np.sqrt(n)), 16)
    src = np.arange(64)
    cap = n + batches * m + 64

    # ---- streaming path: one dataset, consumers patch at their next query
    ds = DynamicDataset(x, capacity=cap, journal_limit=4 * batches)
    nbr = NeighborSampler(ds.x_pad, ker, dataset=ds, exact_blocks=True,
                          block_size=bs, seed=0)
    deg = DegreeSampler(nbr.blocks, seed=1, dataset=ds)
    deg.sample(8)                 # build the initial CDF outside the clock
    nbr.sample(src)
    plan = _mutation_plan(rng, n, d, m, batches + 1)
    warmup, plan = plan[0], plan[1:]
    mrng = np.random.default_rng(7)

    def stream_batch(batch):
        _apply(ds, batch, mrng)
        deg.sample(8)             # folds the coalesced degree/CDF patch in
        nbr.sample(src)           # folds the level-1 patch in

    stream_batch(warmup)          # compile the patch programs off-clock
    # drain warmup's async dispatches BEFORE the clock starts, and every
    # in-flight device op (mutation scatters the queries didn't pull)
    # before it stops -- steady-state device time only
    jax.block_until_ready((ds.x_pad, ds.x_sq_pad, ds.live_dev))
    t0 = time.perf_counter()
    for batch in plan:
        stream_batch(batch)
    jax.block_until_ready((ds.x_pad, ds.x_sq_pad, ds.live_dev))
    t_stream = time.perf_counter() - t0
    assert deg.rebuilds == 0, "journal gap hit -- benchmark mis-sized"

    # ---- rebuild baseline: frozen engines reconstructed after every batch
    ds2 = DynamicDataset(x, capacity=cap, journal_limit=4 * batches)
    mrng = np.random.default_rng(7)

    def rebuild_batch(batch):
        _apply(ds2, batch, mrng)
        x_live, _ = ds2.live_x()
        nbr2 = NeighborSampler(x_live, ker, exact_blocks=True,
                               block_size=bs, seed=0)
        deg2 = DegreeSampler(nbr2.blocks, seed=1)
        deg2.sample(8)
        nbr2.sample(src)

    rebuild_batch(warmup)
    jax.block_until_ready((ds2.x_pad, ds2.x_sq_pad, ds2.live_dev))
    t0 = time.perf_counter()
    for batch in plan:
        rebuild_batch(batch)
    jax.block_until_ready((ds2.x_pad, ds2.x_sq_pad, ds2.live_dev))
    t_rebuild = time.perf_counter() - t0

    rows = m * batches
    new_rps = rows / t_stream
    old_rps = rows / t_rebuild
    speedup = new_rps / old_rps
    emit(f"streaming_update_n{n}_m{m}", t_stream * 1e6 / batches,
         f"rows_per_sec={new_rps:.0f};rebuild_rows_per_sec={old_rps:.0f};"
         f"speedup={speedup:.1f}")

    payload = {
        "n": n, "d": d, "mutated_rows_per_batch": m, "batches": batches,
        "mutate_frac": m / n, "block_size": bs,
        "streaming_rows_per_sec": new_rps,
        "rebuild_rows_per_sec": old_rps,
        "streaming_sec_per_batch": t_stream / batches,
        "rebuild_sec_per_batch": t_rebuild / batches,
        "speedup": speedup,
        "telemetry": telemetry_block(wall_us=1e6 * t_stream / batches),
    }
    _JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {_JSON_PATH.name}: {speedup:.1f}x update throughput "
          f"over full rebuilds at n={n}, {100 * m / n:.1f}% rows/batch")


if __name__ == "__main__":
    run(quick=True)
