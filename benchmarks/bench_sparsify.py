"""Figure 4 / Section 7.1 reproduction: spectral sparsification + clustering
on the paper's Nested and Rings datasets.

derived = "acc=<cluster accuracy>;size_reduction=<x>;eig_speedup=<x>"

Paper claims: 2.5% (Nested) / 3.3% (Rings) of edges preserve the spectral
clustering (99.5% / 100% accuracy), a ~41x size reduction, and 4.5x faster
eigenvector computation on the sparse graph.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.cluster.spectral import (cluster_accuracy,
                                         laplacian_eigenvectors,
                                         spectral_cluster)
from repro.core.kernels_fn import gaussian, median_bandwidth
from repro.core.sparsify import spectral_sparsify
from repro.data.synthetic_points import nested, rings


def _dense_eig_time(k: np.ndarray, kk: int, iters: int = 100,
                    guard: int = 4) -> float:
    """Subspace iteration on the dense normalized adjacency -- IDENTICAL
    block size (k + guard) and iteration count to the sparse path, so the
    comparison isolates the matvec cost (n^2 dense vs 2m sparse)."""
    d = np.maximum(k.sum(1) - 1, 1e-12)
    dm = 1.0 / np.sqrt(d)
    nadj = (dm[:, None] * (k - np.eye(len(k)))) * dm[None, :]
    rng = np.random.default_rng(0)
    q = np.linalg.qr(rng.standard_normal((len(k), kk + guard)))[0]
    t0 = time.perf_counter()
    for _ in range(iters):
        q = np.linalg.qr(nadj @ q + q)[0]
    return time.perf_counter() - t0


def run(quick: bool = False):
    n_nested = 1200 if quick else 2500
    n_rings = 800 if quick else 1500
    rows = []
    cases = [
        ("nested", *nested(n=n_nested, seed=0), 0.3, 0.025),
        ("rings", *rings(n=n_rings, seed=0), None, 0.033),
    ]
    for name, x, lab, bw, frac in cases:
        if bw is None:
            bw = 0.25 * median_bandwidth(jnp.asarray(x))
        ker = gaussian(bandwidth=bw)
        n = x.shape[0]
        total_edges = n * (n - 1) / 2
        budget = int(frac * total_edges)
        t0 = time.perf_counter()
        g = spectral_sparsify(x, ker, num_edges=budget, estimator="exact",
                              exact_blocks=True, seed=0)
        t_sp = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = spectral_cluster(g, 2, seed=0)
        t_cluster_sparse = time.perf_counter() - t0
        acc = cluster_accuracy(res.labels, lab, 2)
        k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
        t_dense = _dense_eig_time(k, 2, iters=100)
        t0 = time.perf_counter()
        laplacian_eigenvectors(g, 2, iters=100, seed=0)
        t_sparse = time.perf_counter() - t0
        rows.append(emit(
            f"sparsify/{name}/{frac:.3f}", t_sp * 1e6,
            f"acc={acc:.4f};size_reduction={total_edges / budget:.1f}x;"
            f"eig_speedup={t_dense / max(t_sparse, 1e-9):.1f}x;"
            f"kernel_evals={g.kernel_evals}"))
    return rows
