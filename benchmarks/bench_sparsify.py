"""Spectral sparsification: engine benchmark + Figure 4 / Section 7.1 repro.

Part 1 (engine): the fused Algorithm 5.1 edge pipeline (DESIGN.md §6 --
device prefix-CDF vertex draw + depth-2 neighbor draw + reverse probability
+ reweighting as ONE ``lax.scan`` program) against a FROZEN copy of the
PR-1 host loop (five device round-trips per batch: deg.sample, nbr.sample,
nbr.prob_of, deg.prob, kernel.pairs).  Writes ``BENCH_sparsify.json`` with
inner-loop throughput, the speedup, relative Laplacian spectral error for
both paths, and the kernel_evals / kde_queries counter audit against the
analytic counts.

derived = "edges_per_sec=<new>;host_edges_per_sec=<old>;speedup=<x>"

Part 2 (figure4): sparsify + spectral clustering on the paper's Nested and
Rings datasets.  Paper claims: 2.5% / 3.3% of edges preserve the clustering
(99.5% / 100% accuracy), ~41x size reduction, 4.5x faster eigensolve.

derived = "acc=<cluster accuracy>;size_reduction=<x>;eig_speedup=<x>"
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.cluster.spectral import (cluster_accuracy,
                                         laplacian_eigenvectors,
                                         spectral_cluster)
from repro.core.kernels_fn import Kernel, gaussian, median_bandwidth
from repro.core.sampling.edge import NeighborSampler
from repro.core.sampling.vertex import DegreeSampler
from repro.core.sparsify import SparseGraph, spectral_sparsify
from repro.data.synthetic_points import nested, rings
from repro.obs.export import telemetry_block

_JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_sparsify.json"


# --------------------------------------------------------------------- #
# Frozen PR-1 host loop (Algorithm 5.1 steps (a)-(d) with one device
# round-trip per step) -- the baseline every engine change is measured
# against.  Do not "fix" this copy; it is the reference implementation.
# --------------------------------------------------------------------- #
def _host_loop_edges(deg: DegreeSampler, nbr: NeighborSampler, kernel: Kernel,
                     t: int, batch: int = 512):
    xd = nbr.x
    srcs, dsts, ws = [], [], []
    for lo in range(0, t, batch):
        b = min(batch, t - lo)
        u = deg.sample(b)
        v, q_uv = nbr.sample(u)
        q_vu = nbr.prob_of(v, u)
        p_u, p_v = deg.prob(u), deg.prob(v)
        q_edge = p_u * q_uv + p_v * q_vu          # Alg 5.1 step (d)
        w = 1.0 / (t * np.maximum(q_edge, 1e-30))
        kuv = np.asarray(kernel.pairs(xd[jnp.asarray(u)], xd[jnp.asarray(v)]))
        srcs.append(u)
        dsts.append(v)
        ws.append(w * kuv)
    return (np.concatenate(srcs), np.concatenate(dsts), np.concatenate(ws))


def _time(fn, repeats=3, warmup=1):
    """Best-of-N FENCED wall seconds via ``obs.Timer`` (the return value
    of ``fn`` is ``block_until_ready``'d before the clock stops); min is
    robust against background load on shared CPUs."""
    from repro.obs.metrics import Timer
    return Timer("bench").timeit(fn, repeats=repeats, warmup=warmup,
                                 reduce="min") / 1e6


def _spectral_error(g: SparseGraph, l_true: np.ndarray, probes: int = 24,
                    seed: int = 1) -> float:
    """max |v' L_sp v / v' L v - 1| over random centered probes."""
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((l_true.shape[0], probes))
    v -= v.mean(0)
    l_sp = g.laplacian_dense()
    ratios = np.einsum("ij,ij->j", v, l_sp @ v) / \
        np.einsum("ij,ij->j", v, l_true @ v)
    return float(np.abs(ratios - 1.0).max())


def _engine(quick: bool):
    rows, results = [], []
    n = 4096 if quick else 16384
    t, batch, d, spb = (4096, 512, 16, 16)
    batch_fused = 1024  # the fused scan's default device batch (sparsify.py)
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.5, (n, d)).astype(np.float32)
    ker = gaussian(bandwidth=4.0)

    # fused path: samplers built once, inner loop = one scan program
    nbr_f = NeighborSampler(x, ker, mode="blocked", samples_per_block=spb,
                            seed=2)
    deg_f = DegreeSampler(nbr_f.blocks, seed=1)
    cdf, degs = deg_f.cdf_device, deg_f.degrees_device
    t_fused = _time(lambda: nbr_f.edge_batches(cdf, degs, deg_f.total, t,
                                               batch=batch_fused),
                    repeats=5, warmup=1)

    # frozen PR-1 host loop over the same engine primitives, at the PR-1
    # default batch size
    nbr_h = NeighborSampler(x, ker, mode="blocked", samples_per_block=spb,
                            seed=2)
    deg_h = DegreeSampler(nbr_h.blocks, seed=1)
    t_host = _time(lambda: _host_loop_edges(deg_h, nbr_h, ker, t,
                                            batch=batch),
                   repeats=3, warmup=1)

    eps_fused = t / t_fused
    eps_host = t / t_host
    speedup = t_host / t_fused
    rows.append(emit(
        f"sparsify/inner_loop/n={n}", t_fused * 1e6,
        f"edges_per_sec={eps_fused:.0f};host_edges_per_sec={eps_host:.0f};"
        f"speedup={speedup:.1f}x"))

    # spectral error + counter audit at a size where the dense Laplacian
    # is cheap to materialize
    n_sp = 1024 if quick else 2048
    t_sp = 16 * n_sp
    x_sp = rng.normal(0, 0.35, (n_sp, 8)).astype(np.float32)
    ker_sp = gaussian(bandwidth=3.0)
    k_sp = np.asarray(ker_sp.matrix(jnp.asarray(x_sp)), np.float64)
    np.fill_diagonal(k_sp, 0.0)
    l_true = np.diag(k_sp.sum(1)) - k_sp

    g = spectral_sparsify(x_sp, ker_sp, num_edges=t_sp,
                          estimator="stratified", samples_per_block=spb,
                          seed=0, batch=batch)
    err_fused = _spectral_error(g, l_true)

    nbr_h2 = NeighborSampler(x_sp, ker_sp, mode="blocked",
                             samples_per_block=spb, seed=2)
    deg_h2 = DegreeSampler(nbr_h2.blocks, seed=1)
    u, v, w = _host_loop_edges(deg_h2, nbr_h2, ker_sp, t_sp, batch=batch)
    g_host = SparseGraph(n_sp, u.astype(np.int64), v.astype(np.int64), w)
    err_host = _spectral_error(g_host, l_true)

    # analytic counter audit (stratified level-1 reads, shared estimator)
    bs, nb = nbr_h2.block_size, nbr_h2.num_blocks
    drawn = ((t_sp + batch - 1) // batch) * batch
    want_evals = n_sp * nb * spb + drawn * (nb * spb + bs + 1)
    want_queries = n_sp + drawn
    counters_ok = (g.kernel_evals == want_evals
                   and g.kde_queries == want_queries)
    rows.append(emit(
        f"sparsify/spectral_error/n={n_sp}", 0.0,
        f"fused={err_fused:.4f};host_loop={err_host:.4f};"
        f"counters_ok={counters_ok}"))

    results.append(dict(
        n=n, t=t, batch=dict(fused=batch_fused, host_loop=batch), d=d,
        samples_per_block=spb,
        inner_loop_sec=dict(fused=t_fused, host_loop=t_host),
        edges_per_sec=dict(fused=eps_fused, host_loop=eps_host),
        speedup=speedup,
        spectral_error=dict(n=n_sp, t=t_sp, fused=err_fused,
                            host_loop=err_host),
        counters=dict(kernel_evals=g.kernel_evals,
                      kernel_evals_analytic=want_evals,
                      kde_queries=g.kde_queries,
                      kde_queries_analytic=want_queries,
                      ok=counters_ok)))
    _JSON_PATH.write_text(json.dumps(dict(
        benchmark="bench_sparsify", backend=jax.default_backend(),
        quick=quick, telemetry=telemetry_block(),
        results=results), indent=2) + "\n")
    return rows


# --------------------------------------------------------------------- #
# Figure 4 / Section 7.1
# --------------------------------------------------------------------- #
def _dense_eig_time(k: np.ndarray, kk: int, iters: int = 100,
                    guard: int = 4) -> float:
    """Subspace iteration on the dense normalized adjacency -- IDENTICAL
    block size (k + guard) and iteration count to the sparse path, so the
    comparison isolates the matvec cost (n^2 dense vs 2m sparse)."""
    d = np.maximum(k.sum(1) - 1, 1e-12)
    dm = 1.0 / np.sqrt(d)
    nadj = (dm[:, None] * (k - np.eye(len(k)))) * dm[None, :]
    rng = np.random.default_rng(0)
    q = np.linalg.qr(rng.standard_normal((len(k), kk + guard)))[0]
    t0 = time.perf_counter()
    for _ in range(iters):
        q = np.linalg.qr(nadj @ q + q)[0]
    return time.perf_counter() - t0


def _figure4(quick: bool):
    n_nested = 1200 if quick else 2500
    n_rings = 800 if quick else 1500
    rows = []
    cases = [
        ("nested", *nested(n=n_nested, seed=0), 0.3, 0.025),
        ("rings", *rings(n=n_rings, seed=0), None, 0.033),
    ]
    for name, x, lab, bw, frac in cases:
        if bw is None:
            bw = 0.25 * median_bandwidth(jnp.asarray(x))
        ker = gaussian(bandwidth=bw)
        n = x.shape[0]
        total_edges = n * (n - 1) / 2
        budget = int(frac * total_edges)
        t0 = time.perf_counter()
        g = spectral_sparsify(x, ker, num_edges=budget, estimator="exact",
                              exact_blocks=True, seed=0)
        t_sp = time.perf_counter() - t0
        res = spectral_cluster(g, 2, seed=0)
        acc = cluster_accuracy(res.labels, lab, 2)
        k = np.asarray(ker.matrix(jnp.asarray(x)), np.float64)
        t_dense = _dense_eig_time(k, 2, iters=100)
        t0 = time.perf_counter()
        laplacian_eigenvectors(g, 2, iters=100, seed=0)
        t_sparse = time.perf_counter() - t0
        rows.append(emit(
            f"sparsify/{name}/{frac:.3f}", t_sp * 1e6,
            f"acc={acc:.4f};size_reduction={total_edges / budget:.1f}x;"
            f"eig_speedup={t_dense / max(t_sparse, 1e-9):.1f}x;"
            f"kernel_evals={g.kernel_evals}"))
    return rows


def run(quick: bool = False):
    return _engine(quick) + _figure4(quick)


if __name__ == "__main__":
    run(quick=True)
