"""Shared benchmark utilities.  Every benchmark prints CSV rows:
``name,us_per_call,derived`` (derived = benchmark-specific metric)."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us: float, derived) -> str:
    row = f"{name},{us:.1f},{derived}"
    print(row, flush=True)
    return row
