"""Shared benchmark utilities.  Every benchmark prints CSV rows:
``name,us_per_call,derived`` (derived = benchmark-specific metric).

Timing routes through ``obs.Timer`` (DESIGN.md §15.2): the timed
callable's return value is ``jax.block_until_ready``'d before the clock
stops, so every number is realized device time, never an async-dispatch
tail.  Callables that already fence internally (``np.asarray`` on the
result) pay only a no-op re-fence."""
from __future__ import annotations

from typing import Callable

from repro.obs.metrics import Timer


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1,
           name: str = "bench") -> float:
    """Median FENCED wall time in microseconds (``obs.Timer``)."""
    return Timer(name).timeit(fn, repeats=repeats, warmup=warmup)


def emit(name: str, us: float, derived) -> str:
    row = f"{name},{us:.1f},{derived}"
    print(row, flush=True)
    return row
