"""Table 2 analog: measured KDE-query / kernel-eval budgets for every
reduction and application.

derived = "kernel_evals=<n>;frac_of_n2=<f>" -- each application's measured
cost relative to materializing the kernel matrix (n^2 evals).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.eigen import top_eigenvalue
from repro.core.graph.arboricity import estimate_arboricity
from repro.core.graph.triangles import estimate_triangle_weight
from repro.core.kde.base import make_estimator
from repro.core.kernels_fn import gaussian
from repro.core.lowrank import fkv_lowrank
from repro.core.sampling.edge import NeighborSampler
from repro.core.sampling.vertex import DegreeSampler
from repro.core.sampling.walks import random_walks
from repro.core.sparsify import spectral_sparsify
from repro.core.spectrum import approximate_spectrum


def run(quick: bool = False):
    n = 1000 if quick else 2000
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.35, (n, 6)).astype(np.float32)
    ker = gaussian(bandwidth=2.0)
    n2 = float(n * n)
    rows = []

    est = make_estimator("stratified", x, ker, seed=0)
    ds = DegreeSampler(est, seed=1)
    rows.append(emit("primitive/degree_preprocessing", 0.0,
                     f"kernel_evals={est.evals};frac_of_n2={est.evals/n2:.4f}"))

    nb = NeighborSampler(x, ker, mode="blocked", samples_per_block=8, seed=2)
    nb.sample(np.zeros(256, np.int64))
    per_sample = nb.evals / 256
    rows.append(emit("primitive/neighbor_sample", 0.0,
                     f"kernel_evals={per_sample:.0f};frac_of_n2={per_sample/n2:.6f}"))

    e0 = nb.evals
    random_walks(nb, np.zeros(64, np.int64), 8)
    per_walk = (nb.evals - e0) / 64
    rows.append(emit("primitive/random_walk_len8", 0.0,
                     f"kernel_evals={per_walk:.0f};frac_of_n2={per_walk/n2:.6f}"))

    g = spectral_sparsify(x, ker, num_edges=8 * n, estimator="stratified",
                          samples_per_block=8, seed=0)
    rows.append(emit("app/spectral_sparsification", 0.0,
                     f"kernel_evals={g.kernel_evals};frac_of_n2={g.kernel_evals/n2:.3f}"))

    res = fkv_lowrank(x, ker, rank=8, num_rows=200, estimator="rs", seed=0)
    rows.append(emit("app/low_rank_approx", 0.0,
                     f"kernel_evals={res.kernel_evals};frac_of_n2={res.kernel_evals/n2:.3f}"))

    er = top_eigenvalue(x, ker, t=150, seed=0)
    rows.append(emit("app/top_eigenvalue", 0.0,
                     f"kernel_evals={er.kernel_evals};frac_of_n2={er.kernel_evals/n2:.3f}"))

    sp = approximate_spectrum(x, ker, length=6, num_sources=12,
                              walks_per_source=24, seed=0)
    rows.append(emit("app/spectrum_emd", 0.0,
                     f"kernel_evals={sp.kernel_evals};frac_of_n2={sp.kernel_evals/n2:.3f}"))

    tr = estimate_triangle_weight(x, ker, num_edges=200, neighbor_samples=8,
                                  estimator="stratified", seed=0)
    rows.append(emit("app/triangle_weight", 0.0,
                     f"kernel_evals={tr.kernel_evals};frac_of_n2={tr.kernel_evals/n2:.3f}"))

    ar = estimate_arboricity(x, ker, num_edges=4 * n, estimator="stratified",
                             seed=0)
    rows.append(emit("app/arboricity", 0.0,
                     f"kernel_evals={ar.kernel_evals};frac_of_n2={ar.kernel_evals/n2:.3f}"))
    return rows
