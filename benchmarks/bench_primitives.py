"""Table 2 analog: measured KDE-query / kernel-eval budgets for every
reduction and application.

derived = "kernel_evals=<n>;frac_of_n2=<f>" -- each application's measured
cost relative to materializing the kernel matrix (n^2 evals).

``--check`` (the CI perf-smoke step) reruns the quick configuration and
fails if any eval counter drifts from the pinned ``QUICK_BASELINE`` or if
the sampler's accumulated status word carries a ``guards.FATAL`` bit.
The ``*_realized`` entries are read off the device counter words
(DESIGN.md §15.1) and pin host/device eval parity, not just the host
arithmetic.  The
counters are exact: every primitive here is seeded, so a changed count
means the sampling schedule changed -- which must be a deliberate edit to
this baseline, never an accident.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit
from repro.core.eigen import top_eigenvalue
from repro.core.graph.arboricity import estimate_arboricity
from repro.core.graph.triangles import estimate_triangle_weight
from repro.core.kde.base import make_estimator
from repro.core.kernels_fn import gaussian
from repro.core.lowrank import fkv_lowrank
from repro.core.sampling.edge import NeighborSampler
from repro.core.sampling.vertex import DegreeSampler
from repro.core.sampling.walks import random_walks
from repro.core.sparsify import spectral_sparsify
from repro.core.spectrum import approximate_spectrum

# Pinned quick-mode eval counters (n=1000, seeds as in ``_measure``).
# Regenerate deliberately with ``python -m benchmarks.bench_primitives
# --quick --print-baseline`` after any intentional schedule change.
QUICK_BASELINE = {
    "degree_preprocessing": 64000,
    "degree_preprocessing_realized": 64000,
    "neighbor_sample": 75520,
    "neighbor_sample_realized": 75520,
    "random_walk_len8": 151040,
    "random_walk_len8_realized": 151040,
    "spectral_sparsification": 2688832,
    "low_rank_approx": 280000,
    "top_eigenvalue": 22500,
    "spectrum_emd": 1781568,
    "triangle_weight": 685000,
    "arboricity": 2821760,
}


def _measure(quick: bool):
    """Run every primitive/application once; return (rows, counters,
    accumulated sampler status word)."""
    n = 1000 if quick else 2000
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.35, (n, 6)).astype(np.float32)
    ker = gaussian(bandwidth=2.0)
    n2 = float(n * n)
    rows = []
    counters = {}

    est = make_estimator("stratified", x, ker, seed=0)
    DegreeSampler(est, seed=1)
    counters["degree_preprocessing"] = int(est.evals)
    # realized device evals from the counter words (DESIGN.md §15.1);
    # on the flat stratified/blocked pipelines they must equal the
    # analytic counters exactly (asserted in tests/test_fused_apps.py)
    counters["degree_preprocessing_realized"] = \
        int(est.device_counters["evals"])
    rows.append(emit("primitive/degree_preprocessing", 0.0,
                     f"kernel_evals={est.evals};frac_of_n2={est.evals/n2:.4f}"))

    nb = NeighborSampler(x, ker, mode="blocked", samples_per_block=8, seed=2)
    nb.sample(np.zeros(256, np.int64))
    counters["neighbor_sample"] = int(nb.evals)
    counters["neighbor_sample_realized"] = int(nb.device_counters["evals"])
    per_sample = nb.evals / 256
    rows.append(emit("primitive/neighbor_sample", 0.0,
                     f"kernel_evals={per_sample:.0f};frac_of_n2={per_sample/n2:.6f}"))

    e0, r0 = nb.evals, nb.device_counters["evals"]
    random_walks(nb, np.zeros(64, np.int64), 8)
    counters["random_walk_len8"] = int(nb.evals - e0)
    counters["random_walk_len8_realized"] = \
        int(nb.device_counters["evals"] - r0)
    per_walk = (nb.evals - e0) / 64
    rows.append(emit("primitive/random_walk_len8", 0.0,
                     f"kernel_evals={per_walk:.0f};frac_of_n2={per_walk/n2:.6f}"))
    status = int(nb.status)

    g = spectral_sparsify(x, ker, num_edges=8 * n, estimator="stratified",
                          samples_per_block=8, seed=0)
    counters["spectral_sparsification"] = int(g.kernel_evals)
    rows.append(emit("app/spectral_sparsification", 0.0,
                     f"kernel_evals={g.kernel_evals};frac_of_n2={g.kernel_evals/n2:.3f}"))

    res = fkv_lowrank(x, ker, rank=8, num_rows=200, estimator="rs", seed=0)
    counters["low_rank_approx"] = int(res.kernel_evals)
    rows.append(emit("app/low_rank_approx", 0.0,
                     f"kernel_evals={res.kernel_evals};frac_of_n2={res.kernel_evals/n2:.3f}"))

    er = top_eigenvalue(x, ker, t=150, seed=0)
    counters["top_eigenvalue"] = int(er.kernel_evals)
    rows.append(emit("app/top_eigenvalue", 0.0,
                     f"kernel_evals={er.kernel_evals};frac_of_n2={er.kernel_evals/n2:.3f}"))

    sp = approximate_spectrum(x, ker, length=6, num_sources=12,
                              walks_per_source=24, seed=0)
    counters["spectrum_emd"] = int(sp.kernel_evals)
    rows.append(emit("app/spectrum_emd", 0.0,
                     f"kernel_evals={sp.kernel_evals};frac_of_n2={sp.kernel_evals/n2:.3f}"))

    tr = estimate_triangle_weight(x, ker, num_edges=200, neighbor_samples=8,
                                  estimator="stratified", seed=0)
    counters["triangle_weight"] = int(tr.kernel_evals)
    rows.append(emit("app/triangle_weight", 0.0,
                     f"kernel_evals={tr.kernel_evals};frac_of_n2={tr.kernel_evals/n2:.3f}"))

    ar = estimate_arboricity(x, ker, num_edges=4 * n, estimator="stratified",
                             seed=0)
    counters["arboricity"] = int(ar.kernel_evals)
    rows.append(emit("app/arboricity", 0.0,
                     f"kernel_evals={ar.kernel_evals};frac_of_n2={ar.kernel_evals/n2:.3f}"))
    return rows, counters, status


def check_quick() -> None:
    """CI perf-smoke: quick counters must match ``QUICK_BASELINE`` exactly
    and no sampler status word may carry a fatal guard bit."""
    from repro.ft.guards import FATAL, decode_status
    _, counters, status = _measure(quick=True)
    drift = {k: (QUICK_BASELINE.get(k), v) for k, v in counters.items()
             if QUICK_BASELINE.get(k) != v}
    if drift:
        lines = "\n".join(f"  {k}: baseline={b} measured={m}"
                          for k, (b, m) in sorted(drift.items()))
        raise RuntimeError(
            f"eval-counter regression vs QUICK_BASELINE:\n{lines}\n"
            "If the schedule change is intentional, regenerate the baseline "
            "with --print-baseline and update bench_primitives.py.")
    if status & FATAL:
        raise RuntimeError(
            f"sampler status carries fatal guard bits: "
            f"{decode_status(status & FATAL)} (status=0x{status:x})")
    print(f"# check ok: {len(counters)} counters match baseline, "
          f"status=0x{status:x}")


def run(quick: bool = False):
    rows, _, _ = _measure(quick)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="fail on eval-counter/status regressions")
    ap.add_argument("--print-baseline", action="store_true",
                    help="print the measured quick counters as python")
    a = ap.parse_args()
    if a.print_baseline:
        _, counters, _ = _measure(quick=True)
        print("QUICK_BASELINE = {")
        for k, v in counters.items():
            print(f'    "{k}": {v},')
        print("}")
    elif a.check:
        check_quick()
    else:
        run(quick=a.quick)
