"""Framework integration benchmark: kde_attention (the paper's technique as
a decode kernel) vs exact attention.

derived = "max_err=<e>;flops_frac=<f>" -- flops_frac is the modeled compute
fraction of the sub-quadratic path vs the exact path (S/stride + P*bk)/S.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels.kde_attention import ops as ka


def run(quick: bool = False):
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    rows = []
    sizes = [4096, 8192] if quick else [8192, 32768]
    for S in sizes:
        b, hq, hkv, dh = 1, 8, 2, 64
        q = rng.normal(0, 1, (b, hq, dh)).astype(np.float32)
        k = rng.normal(0, 0.05, (b, hkv, S, dh)).astype(np.float32)
        # peaked mass (the realistic long-context regime): planted keys must
        # dominate the S-key background (score ~8 vs ~0 -> e^8 x 40 >> S)
        for h in range(hkv):
            qv = q.reshape(b, hkv, hq // hkv, dh).mean(2)[0, h]
            qv = qv / np.linalg.norm(qv)
            k[0, h, 50:90] += 8.0 * qv
            k[0, h, S // 2:S // 2 + 30] += 6.0 * qv
        v = rng.normal(0, 1, (b, hkv, S, dh)).astype(np.float32)
        qj, kj, vj = jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)
        top_p, bk, stride = 16, 256, 16
        exact = ka.exact_decode_attention(qj, kj, vj)
        out = ka.kde_attention(qj, kj, vj, top_p=top_p, bk=bk, stride=stride)
        err = float(jnp.max(jnp.abs(out - exact))) / \
            max(float(jnp.max(jnp.abs(exact))), 1e-9)
        us = timeit(lambda: ka.kde_attention(
            qj, kj, vj, top_p=top_p, bk=bk, stride=stride).block_until_ready())
        us_exact = timeit(lambda: ka.exact_decode_attention(
            qj, kj, vj).block_until_ready())
        frac = (S / stride + top_p * bk) / S
        rows.append(emit(
            f"kde_attention/S={S}", us,
            f"max_err={err:.4f};flops_frac={frac:.3f};"
            f"exact_us={us_exact:.0f}"))
    return rows
